//! Bench/exhibit: regenerate Fig. 8 — auto-mapper vs expert all-RS
//! dataflow on the chunk accelerator, across several hybrid models and
//! two shared-buffer budgets (the tight one exhibits the paper's
//! "fixed RS fails to map" green-dotted-line cases).
//!
//! Run: cargo bench --bench fig8_automapper

use nasa::accel::{HwConfig, MemoryConfig};
use nasa::mapper::{auto_map, auto_map_reference, MapperConfig};
use nasa::model::{Arch, LayerDesc, OpKind, QuantSpec};
use nasa::report::fig8::{print_rows, rows_to_log, Fig8Row};
use nasa::runtime::Manifest;
use nasa::util::bench::{header, Runner};
use std::path::Path;

fn model_set() -> Vec<Arch> {
    // Searched archs if available; else representative hybrids from the
    // manifest; else synthetic fallbacks.
    let saved = nasa::report::load_archs(Path::new("runs")).unwrap_or_default();
    if saved.len() >= 2 {
        return saved;
    }
    if let Ok(manifest) = Manifest::load(Path::new("artifacts")) {
        if let Ok(sn) = manifest.supernet("hybrid_all_c10") {
            let find = |t_: &str, e: usize, k: usize| {
                sn.cands.iter().position(|c| c.t == t_ && c.e == e && c.k == k).unwrap()
            };
            let mk = |name: &str, ch: Vec<usize>| Arch::from_choices(sn, &ch, name).unwrap();
            return vec![
                mk(
                    "hybrid-all-A",
                    vec![
                        find("conv", 3, 3),
                        find("shift", 3, 3),
                        find("adder", 3, 5),
                        find("conv", 6, 5),
                        find("shift", 1, 3),
                        find("adder", 6, 3),
                    ],
                ),
                mk(
                    "hybrid-all-B",
                    vec![
                        find("shift", 6, 3),
                        find("adder", 6, 3),
                        find("conv", 3, 5),
                        find("shift", 3, 3),
                        find("adder", 3, 3),
                        find("conv", 6, 3),
                    ],
                ),
                mk(
                    "hybrid-shift-A",
                    vec![
                        find("conv", 3, 3),
                        find("shift", 6, 3),
                        find("shift", 3, 5),
                        find("conv", 3, 3),
                        find("shift", 6, 5),
                        find("shift", 3, 3),
                    ],
                ),
                mk(
                    "hybrid-adder-heavy",
                    vec![
                        find("adder", 6, 3),
                        find("adder", 6, 5),
                        find("conv", 3, 3),
                        find("adder", 6, 3),
                        find("shift", 3, 3),
                        find("adder", 6, 5),
                    ],
                ),
            ];
        }
    }
    vec![]
}

fn run_setting(models: &[Arch], mem: MemoryConfig, label: &str) -> Vec<Fig8Row> {
    let q = QuantSpec::default();
    let mut hw = HwConfig::eyeriss_class();
    hw.mem = mem;
    let mut rows = Vec::new();
    for arch in models {
        let accel = hw.build(arch);
        let r = auto_map(&accel, arch, &q, &MapperConfig::for_hw(&hw));
        let Some((m, s)) = &r.best else {
            println!("  {}/{}: nothing feasible!", label, arch.name);
            continue;
        };
        rows.push(Fig8Row {
            model: format!("{} [{}]", arch.name, label),
            rs_edp: r.rs_baseline.as_ref().ok().map(|st| st.edp(accel.clock_hz)),
            auto_edp: s.edp(accel.clock_hz),
            auto_df: format!("{}/{}/{}", m.clp_df.name(), m.slp_df.name(), m.alp_df.name()),
            infeasible_combos: r.combos_infeasible,
        });
    }
    rows
}

fn main() {
    let models = model_set();
    if models.is_empty() {
        println!("no models available (need artifacts/ or runs/) — exhibit skipped");
        return;
    }
    let mut rows = run_setting(&models, MemoryConfig::default(), "108KB GB");
    rows.extend(run_setting(&models, MemoryConfig::tight(), "32KB GB"));
    print_rows(&rows);
    let _ = std::fs::create_dir_all("runs");
    let _ = rows_to_log(&rows, "fig8_bench").save(Path::new("runs"));

    // Timing: the mapper search itself (the L3 hot path of Sec. 4.2) —
    // the chunk-factorized engine against the retained brute-force
    // oracle on the same widened space (now the EDP-aware frontier rule
    // with the full divisor lattice, the default), plus the PR-2-era
    // greedy + lattice-off configuration for the before/after cost.
    println!();
    header();
    let mut runner = Runner::from_args();
    let arch = &models[0];
    let accel = HwConfig::eyeriss_class().build(arch);
    let q = QuantSpec::default();
    let cfg = MapperConfig::default();
    let factored = runner.bench("fig8/auto_map_one_model", || {
        let r = auto_map(&accel, arch, &q, &cfg);
        std::hint::black_box(r.combos_tried);
    });
    let reference = runner.bench("fig8/auto_map_one_model_reference", || {
        let r = auto_map_reference(&accel, arch, &q, &cfg);
        std::hint::black_box(r.combos_tried);
    });
    runner.record_speedup("fig8/speedup_factored_vs_reference", &reference, &factored);
    let greedy_off =
        MapperConfig { greedy_tiling: true, full_tiling_lattice: false, ..Default::default() };
    let greedy = runner.bench("fig8/auto_map_one_model_greedy_nolattice", || {
        let r = auto_map(&accel, arch, &q, &greedy_off);
        std::hint::black_box(r.combos_tried);
    });
    runner.record_speedup(
        "fig8/cost_ratio_frontier_lattice_vs_greedy_nolattice",
        &factored,
        &greedy,
    );
    runner.finish();
}
