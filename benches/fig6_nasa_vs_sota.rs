//! Bench/exhibit: regenerate Fig. 6 — NASA (searched hybrid on the chunk
//! accelerator + auto-mapper) vs the SOTA baseline systems:
//!
//!   * FBNet-style conv-only model on Eyeriss with MACs,
//!   * DeepShift-MobileNetV2 on Eyeriss with Shift Units,
//!   * AdderNet-MobileNetV2 on Eyeriss with Adder Units,
//!   * AdderNet-ResNet32 on the dedicated adder accelerator [21],
//!
//! all under the same 168-MAC-equivalent area budget, CMOS 45nm, 250MHz.
//!
//! The algorithm half is ONE parallel sweep (`coordinator::sweep`): when
//! artifacts/ exists, the hybrid-all and conv-only searches run
//! concurrently over a shared engine (checkpointed under runs/<name>/;
//! NASA_FIG6_RESUME=1 resumes) and their derived archs feed the hardware
//! comparison below. Accuracy columns join from runs/ train logs
//! (populated by the e2e example); without them, EDP ordering (the
//! hardware half of the figure) still prints.
//! Knobs: NASA_FIG6_EPOCHS / NASA_FIG6_SEARCH_EPOCHS / NASA_FIG6_STEPS.
//!
//! Run: cargo bench --bench fig6_nasa_vs_sota

use nasa::accel::{HwConfig, PeKind};
use nasa::coordinator::{run_sweep, save_outcomes, SearchConfig, SweepOptions, SweepRun};
use nasa::mapper::{auto_map, MapperConfig};
use nasa::model::{zoo, Arch, OpKind, QuantSpec};
use nasa::report::fig6::{points_to_log, print_points, Fig6Point};
use nasa::runtime::{Engine, Manifest};
use nasa::util::bench::{env_usize, header, Bench};
use std::path::Path;

/// The algorithm half of Fig. 6 as ONE parallel sweep: search every space
/// the comparison joins (hybrid-all for NASA, conv-only for the FBNet
/// baseline) concurrently through a shared engine, and save the derived
/// archs into runs/ where the hardware half below picks them up. Without
/// artifacts/ this is skipped and the representative fallbacks apply.
fn refresh_searched_archs() {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        return;
    }
    let Ok(manifest) = Manifest::load(dir) else { return };
    let Ok(engine) = Engine::cpu() else {
        println!("(engine unavailable — reusing saved searched archs)");
        return;
    };
    let pretrain = env_usize("NASA_FIG6_EPOCHS", 3);
    let search = env_usize("NASA_FIG6_SEARCH_EPOCHS", 3);
    let steps = env_usize("NASA_FIG6_STEPS", 4);
    let runs: Vec<SweepRun> = ["hybrid_all_c10", "conv_only_c10"]
        .iter()
        .filter(|s| manifest.supernet(s).is_ok())
        .map(|s| {
            let mut cfg = SearchConfig::for_space(s, pretrain, search);
            cfg.steps_per_epoch = steps;
            SweepRun { name: format!("search_{s}"), cfg }
        })
        .collect();
    if runs.is_empty() {
        return;
    }
    let opts = SweepOptions {
        jobs: 0,
        out_dir: Path::new("runs").to_path_buf(),
        checkpoint: true,
        resume: std::env::var("NASA_FIG6_RESUME").is_ok(),
    };
    let t0 = std::time::Instant::now();
    match run_sweep(&engine, &manifest, &runs, &opts) {
        Ok(results) => match save_outcomes(&results, &opts.out_dir) {
            Ok(ok) => println!(
                "fig6 search sweep: {ok}/{} spaces searched in {:.0}s (shared engine)",
                results.len(),
                t0.elapsed().as_secs_f64()
            ),
            Err(e) => println!(
                "fig6 search sweep: saving outcomes failed ({e}) — exhibit may use stale archs"
            ),
        },
        Err(e) => println!("fig6 search sweep failed ({e}); reusing saved archs"),
    }
}

fn searched_hybrid() -> Option<Arch> {
    // Prefer a searched arch from runs/, else representative via manifest.
    let saved = nasa::report::load_archs(Path::new("runs")).unwrap_or_default();
    if let Some(a) = saved.iter().find(|a| a.name.contains("hybrid_all")) {
        return Some(a.clone());
    }
    let manifest = Manifest::load(Path::new("artifacts")).ok()?;
    let sn = manifest.supernet("hybrid_all_c10").ok()?;
    let find = |t_: &str, e: usize, k: usize| {
        sn.cands.iter().position(|c| c.t == t_ && c.e == e && c.k == k).unwrap()
    };
    Arch::from_choices(
        sn,
        &[
            find("conv", 3, 3),
            find("shift", 3, 3),
            find("adder", 3, 5),
            find("conv", 6, 5),
            find("shift", 1, 3),
            find("adder", 6, 3),
        ],
        "hybrid-all (repr.)",
    )
    .ok()
}

fn conv_searched() -> Option<Arch> {
    let saved = nasa::report::load_archs(Path::new("runs")).unwrap_or_default();
    // Prefer the conv-twin of the searched hybrid (iso-architecture: same
    // (E,K) geometry with every block multiplication-based) — the paper's
    // comparisons hold the accuracy/size point fixed; a conv-only search
    // at a different lambda operating point would not.
    if let Some(a) = saved.iter().find(|a| a.name.contains("conv_twin")) {
        return Some(a.clone());
    }
    if let Some(a) = saved.iter().find(|a| a.name.contains("conv_only")) {
        return Some(a.clone());
    }
    let manifest = Manifest::load(Path::new("artifacts")).ok()?;
    let sn = manifest.supernet("conv_only_c10").ok()?;
    let find = |e: usize, k: usize| {
        sn.cands.iter().position(|c| c.t == "conv" && c.e == e && c.k == k).unwrap()
    };
    Arch::from_choices(
        sn,
        &[find(3, 3), find(3, 3), find(6, 3), find(3, 5), find(6, 5), find(3, 3)],
        "FBNet-like (repr.)",
    )
    .ok()
}

fn acc_from_runs(space: &str) -> Option<f64> {
    let logs = nasa::report::load_runs(Path::new("runs")).ok()?;
    logs.iter()
        .find(|l| l.name == format!("train_{space}"))
        .and_then(|l| l.scalar("test_acc_fp32"))
}

fn main() {
    refresh_searched_archs();
    let q = QuantSpec::default();
    // Every system in the figure shares ONE hardware point: the default
    // 168-MAC-equivalent class. Only the PE family / host differs per row.
    let hw = HwConfig::eyeriss_class();
    let mut points = Vec::new();

    // --- NASA: hybrid searched model on chunk accel + auto-mapper ---
    let hybrid = searched_hybrid();
    if let Some(arch) = &hybrid {
        let accel = hw.build(arch);
        if let Some((_, s)) = auto_map(&accel, arch, &q, &MapperConfig::for_hw(&hw)).best {
            points.push(Fig6Point {
                system: "NASA (hybrid + chunk accel + auto-mapper)".into(),
                acc: acc_from_runs("hybrid_all_c10").unwrap_or(f64::NAN),
                edp_pj_s: s.edp(accel.clock_hz),
            });
        }
    }

    // --- FBNet-on-Eyeriss(MAC) ---
    if let Some(arch) = &conv_searched() {
        let ey = hw.build_eyeriss(PeKind::Mac);
        if let Ok(s) = ey.simulate(arch, &q) {
            let acc = if arch.name.contains("twin") {
                acc_from_runs("conv_twin").unwrap_or(f64::NAN)
            } else {
                acc_from_runs("conv_only_c10").unwrap_or(f64::NAN)
            };
            points.push(Fig6Point {
                system: "FBNet-style conv [22] on Eyeriss-MAC".into(),
                acc,
                edp_pj_s: s.edp(ey.clock_hz),
            });
        }
    }

    // --- DeepShift-MBv2 on Eyeriss(Shift) ---
    let ds = zoo::mobilenet_v2_like(OpKind::Shift, 16, 10, 500);
    let ey_s = hw.build_eyeriss(PeKind::ShiftUnit);
    if let Ok(s) = ey_s.simulate(&ds, &q) {
        points.push(Fig6Point {
            system: "DeepShift-MBv2 [6] on Eyeriss-Shift".into(),
            acc: f64::NAN,
            edp_pj_s: s.edp(ey_s.clock_hz),
        });
    }

    // --- AdderNet-MBv2 on Eyeriss(Adder) ---
    let an = zoo::mobilenet_v2_like(OpKind::Adder, 16, 10, 500);
    let ey_a = hw.build_eyeriss(PeKind::AdderUnit);
    if let Ok(s) = ey_a.simulate(&an, &q) {
        points.push(Fig6Point {
            system: "AdderNet-MBv2 [20] on Eyeriss-Adder".into(),
            acc: f64::NAN,
            edp_pj_s: s.edp(ey_a.clock_hz),
        });
    }

    // --- AdderNet-ResNet32 on the dedicated accelerator [21] ---
    let rn = zoo::resnet32_adder_like(16, 10);
    let ded = hw.build_addernet();
    if let Ok(s) = ded.simulate(&rn, &q) {
        points.push(Fig6Point {
            system: "AdderNet-ResNet32 on dedicated accel [21]".into(),
            acc: f64::NAN,
            edp_pj_s: s.edp(ded.clock_hz),
        });
    }

    print_points(&points);
    let _ = std::fs::create_dir_all("runs");
    let _ = points_to_log(&points, "fig6_bench").save(Path::new("runs"));

    // Headline ratios (Sec. 5.2): NASA EDP vs FBNet-on-Eyeriss.
    if let (Some(nasa_p), Some(fbnet_p)) = (
        points.iter().find(|p| p.system.starts_with("NASA")),
        points.iter().find(|p| p.system.starts_with("FBNet")),
    ) {
        println!(
            "\nheadline: NASA EDP is {:.1}% lower than FBNet-on-Eyeriss (paper: 51.5-59.7%)",
            (1.0 - nasa_p.edp_pj_s / fbnet_p.edp_pj_s) * 100.0
        );
    }

    println!();
    header();
    if let Some(arch) = &hybrid {
        let accel = hw.build(arch);
        Bench::new("fig6/nasa_pipeline_simulation").run(|| {
            let m = nasa::accel::Mapping::all_rs(arch.layers.len());
            std::hint::black_box(accel.simulate(arch, &m, &q).map(|s| s.energy_pj).ok());
        });
    }
}
