//! Offline API-compatible subset of the `anyhow` error-handling crate.
//!
//! The reproduction's build environment ships no crates.io registry, so
//! this workspace member provides the exact slice of the anyhow 1.x API
//! the `nasa` crate uses:
//!
//! * [`Error`] — an opaque error value carrying a human-readable context
//!   chain (outermost context first, root cause last),
//! * [`Result`] — `std::result::Result` defaulted to [`Error`],
//! * the [`Context`] extension trait (`.context(..)` / `.with_context(..)`)
//!   on both `Result` and `Option`,
//! * the [`anyhow!`], [`bail!`] and [`ensure!`] macros.
//!
//! Semantics mirror the real crate where this repository depends on them:
//! `Display` prints only the outermost message (tests assert on it),
//! `Debug` prints the full `Caused by:` chain (what `fn main() -> Result`
//! shows on error), and any `std::error::Error + Send + Sync + 'static`
//! converts via `?` / `Into`.

use std::fmt::{self, Debug, Display};

/// An error value: a chain of human-readable messages, outermost context
/// first, root cause last. Deliberately not `std::error::Error` itself —
/// exactly like the real `anyhow::Error` — so the blanket `From` impl
/// below stays coherent.
pub struct Error {
    msgs: Vec<String>,
}

impl Error {
    /// Construct from a single displayable message.
    pub fn msg<M: Display>(message: M) -> Error {
        Error { msgs: vec![message.to_string()] }
    }

    /// Wrap with an outer context message (the `Context` trait calls this).
    pub fn context<C: Display>(mut self, context: C) -> Error {
        self.msgs.insert(0, context.to_string());
        self
    }

    /// The message chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.msgs.iter().map(|s| s.as_str())
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.msgs.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.msgs.first().map(|s| s.as_str()).unwrap_or("unknown error"))
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self)?;
        if self.msgs.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for m in &self.msgs[1..] {
                write!(f, "\n    {m}")?;
            }
        }
        Ok(())
    }
}

/// Any standard error converts into [`Error`], capturing its full
/// `source()` chain. This is what makes `?` work in `anyhow::Result`
/// functions. (Coherent because `Error` itself is not `std::error::Error`.)
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        Error { msgs }
    }
}

/// `std::result::Result` with the error type defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` (any error convertible into [`Error`], including [`Error`]
/// itself) and to `Option` (where `None` becomes the context message).
pub trait Context<T>: Sized {
    /// Wrap the error (or `None`) with an outer context message.
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T>;

    /// Like [`Context::context`], evaluating the message lazily.
    fn with_context<C: Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string: `anyhow!("bad {x}")`.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`]: `bail!("bad {x}")`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn display_shows_outermost_context_only() {
        let e: Error = Err::<(), _>(io_err())
            .with_context(|| "loading manifest from /tmp".to_string())
            .unwrap_err();
        assert_eq!(e.to_string(), "loading manifest from /tmp");
        assert_eq!(e.root_cause(), "file missing");
    }

    #[test]
    fn debug_shows_cause_chain() {
        let e: Error = Err::<(), _>(io_err()).context("outer").unwrap_err();
        let d = format!("{e:?}");
        assert!(d.contains("outer") && d.contains("Caused by") && d.contains("file missing"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<usize> {
            Ok(s.parse::<usize>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("x").is_err());
    }

    #[test]
    fn context_on_option_and_anyhow_result() {
        let none: Option<u8> = None;
        assert_eq!(none.context("empty").unwrap_err().to_string(), "empty");
        // .context must also chain on an already-anyhow Result.
        let e: Result<u8> = Err(anyhow!("inner"));
        let e = e.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer");
        assert_eq!(e.chain().count(), 2);
    }

    #[test]
    fn macros_format_and_bail() {
        fn f(v: i32) -> Result<i32> {
            ensure!(v >= 0, "negative: {v}");
            if v > 100 {
                bail!("too big: {v}");
            }
            Ok(v)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert!(f(-1).unwrap_err().to_string().contains("negative"));
        assert!(f(200).unwrap_err().to_string().contains("too big"));
    }
}
