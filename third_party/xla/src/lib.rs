//! Type-level stub of the `xla` (xla_extension 0.5.x) PJRT bindings.
//!
//! Purpose: keep the real PJRT execution path in
//! `rust/src/runtime/engine.rs` *compiling* under `--features pjrt` in an
//! environment that cannot link the native `libxla_extension` library.
//! Only the API surface the `nasa` runtime uses is declared; every entry
//! point that would require the native library returns [`Error`] with a
//! "PJRT runtime unavailable" message at run time.
//!
//! Swapping in the real bindings is a one-line dependency change in
//! `rust/Cargo.toml`; no call-site changes are needed because the
//! signatures here mirror xla-rs 0.5.x.

use std::fmt;

/// Error type standing in for `xla::Error`. Implements `std::error::Error`
/// so it converts into `anyhow::Error` via `?` exactly like the real one.
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

impl std::error::Error for Error {}

/// `Result` alias matching the real crate.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT runtime unavailable in this offline build — \
         replace third_party/xla with the real xla_extension bindings to execute"
    ))
}

/// Element types a [`Literal`] can hold (subset: what the runtime uses).
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}
impl NativeType for f64 {}

/// Host-side literal. The stub tracks only the element count so that
/// shape checks upstream behave sensibly; it holds no real buffer.
#[derive(Debug, Clone)]
pub struct Literal {
    element_count: usize,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { element_count: data.len() }
    }

    /// Rank-0 scalar literal.
    pub fn scalar<T: NativeType>(_value: T) -> Literal {
        Literal { element_count: 1 }
    }

    /// Reshape to the given dimensions (element count preserved).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        let n = if dims.is_empty() { 1 } else { n.max(0) } as usize;
        if n != self.element_count {
            return Err(Error(format!(
                "reshape to {dims:?} ({n} elems) from {} elems",
                self.element_count
            )));
        }
        Ok(self.clone())
    }

    /// Total number of elements.
    pub fn element_count(&self) -> usize {
        self.element_count
    }

    /// Copy out as a host vector — requires the real runtime.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    /// Destructure a tuple literal — requires the real runtime.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }
}

/// Types accepted by [`PjRtLoadedExecutable::execute`] (mirrors xla-rs).
pub trait BorrowLiteral {}
impl BorrowLiteral for Literal {}
impl<'a, B: BorrowLiteral> BorrowLiteral for &'a B {}

/// Marker giving a handle type the same auto-traits as the real
/// raw-pointer-backed xla_extension handles: **not** `Send`/`Sync`.
/// This keeps `cargo check --features pjrt` honest — code that shares an
/// engine across threads (the sweep orchestrator) must state its
/// thread-safety assumption explicitly at the engine seam (see the
/// `unsafe impl`s in `rust/src/runtime/engine.rs`) instead of silently
/// relying on the stub being plain data.
#[derive(Debug, Default, Clone, Copy)]
struct NotThreadSafe(std::marker::PhantomData<*const ()>);

/// Device buffer handle returned by execution.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: NotThreadSafe,
}

impl PjRtBuffer {
    /// Fetch the buffer back to the host — requires the real runtime.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A compiled, loaded executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: NotThreadSafe,
}

impl PjRtLoadedExecutable {
    /// Execute with literal arguments — requires the real runtime.
    pub fn execute<L: BorrowLiteral>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient {
    _private: NotThreadSafe,
}

impl PjRtClient {
    /// Create a CPU client — requires the real runtime, so the stub
    /// errors here (the earliest point) with a clear message.
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    /// Backend platform name.
    pub fn platform_name(&self) -> String {
        "stub-unavailable".to_string()
    }

    /// Compile a computation — requires the real runtime.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Parsed HLO module protobuf.
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Parse an HLO-text artifact — requires the real runtime.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    /// Wrap a parsed HLO proto (pure bookkeeping; no runtime needed).
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_bookkeeping_without_runtime() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(l.element_count(), 6);
        assert!(l.reshape(&[2, 3]).is_ok());
        assert!(l.reshape(&[4, 2]).is_err());
        assert_eq!(Literal::scalar(1.5f32).reshape(&[]).unwrap().element_count(), 1);
    }

    #[test]
    fn runtime_entry_points_error_clearly() {
        let msg = PjRtClient::cpu().unwrap_err().to_string();
        assert!(msg.contains("PJRT runtime unavailable"), "{msg}");
        let l = Literal::vec1(&[0i32]);
        assert!(l.to_vec::<i32>().is_err());
    }
}
