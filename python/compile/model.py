"""L2: NASA hybrid supernet — forward/backward as pure JAX, AOT-lowered.

Implements Sec. 3 of the paper:
  * FBNet-style macro-architecture (Fig. 3): fixed stem, N searchable
    candidate-block layers, fixed head.
  * Candidate blocks PW -> DW -> PW parameterized by (E, K, T) with
    T in {Conv, Shift, Adder} + a parameter-free Skip (Table 1).
  * Weight sharing across the E dimension for candidates with equal (T, K)
    (Sec. 3.1 "shared weights ... among the channel dimension E").
  * Gumbel-Softmax candidate mixing with external noise/mask/temperature
    (Eqs. 6-7) — the mask carries both the top-k path masking and the PGP
    stage gating, both computed by the rust coordinator.
  * Loss = CE + lambda * sum_l sum_i gs_li * cost_li (Eq. 5), with the
    per-candidate hardware cost table computed in rust (scaled FLOPs,
    Sec. 3.3) and passed in as an input.

Channel-masked E dimension (FBNetV2 [18], which the paper cites): the
three E variants of a (T, K) block share ONE block evaluation at maximum
width; the E choice enters as a gs-weighted channel mask. This is both
the memory-saving trick of [18] and — crucially here — a ~3x reduction of
the AOT graph that the xla_extension 0.5.1 CPU compiler must chew
through. With a one-hot alpha the masked block is EXACTLY the E-sliced
block (adder layers use a masked l1 contraction to preserve this, see
kernels/ref.py::adder_pw_masked_ref), so derived-child training/eval
through the supernet artifact is exact.

Everything here is traced ONCE by aot.py into HLO text; at run time the
rust coordinator owns alphas, masks, optimizers and schedules, and feeds
this graph through PJRT.

Two operator backends with identical semantics:
  * use_pallas=False — pure jnp (used for the supernet train/eval
    artifacts),
  * use_pallas=True  — the L1 Pallas kernels (interpret mode; used for the
    fixed-child inference artifacts so the kernels sit on the executed
    rust hot path).
pytest asserts the two backends agree to float tolerance.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .kernels import adder_pw, conv_pw, dw_apply, shift_pw
from .kernels import ref

# ---------------------------------------------------------------------------
# Search-space definition (Table 1) — MUST stay in sync with rust via the
# manifest emitted by aot.py (rust never re-derives this independently).
# ---------------------------------------------------------------------------

EK_CHOICES: List[Tuple[int, int]] = [(1, 3), (3, 3), (6, 3), (1, 5), (3, 5), (6, 5)]
E_CHOICES: List[int] = [1, 3, 6]
K_CHOICES: List[int] = [3, 5]
E_MAX = 6

SPACE_TYPES: Dict[str, List[str]] = {
    "conv_only": ["conv"],  # FBNet baseline space
    "hybrid_shift": ["conv", "shift"],
    "hybrid_adder": ["conv", "adder"],
    "hybrid_all": ["conv", "shift", "adder"],
}


def candidates(space: str) -> List[Dict[str, Any]]:
    """Ordered candidate list for one searchable layer of `space`.

    conv_only: 7, hybrid_shift/adder: 13, hybrid_all: 19 (matches the
    paper's 6 * |T| + 1 count).
    """
    cands: List[Dict[str, Any]] = []
    for t in SPACE_TYPES[space]:
        for e, k in EK_CHOICES:
            cands.append({"t": t, "e": e, "k": k})
    cands.append({"t": "skip"})
    return cands


@dataclass
class SupernetConfig:
    """Macro-architecture (Fig. 3 left). `plan` lists (cout, stride) per
    searchable layer."""

    space: str = "hybrid_all"
    input_hw: int = 16
    input_ch: int = 3
    num_classes: int = 10
    batch: int = 16
    stem_ch: int = 16
    # Stem stride 2 keeps every searchable layer at <=8x8 spatial —
    # the adder layers' broadcast l1 contraction is the CPU cost driver
    # and scales with M = B*H*W (see DESIGN.md §Perf).
    stem_stride: int = 2
    head_ch: int = 128
    plan: List[Tuple[int, int]] = field(
        default_factory=lambda: [(16, 1), (24, 2), (24, 1), (32, 2), (32, 1), (64, 1)]
    )

    @property
    def n_layers(self) -> int:
        return len(self.plan)

    @property
    def n_cand(self) -> int:
        return len(candidates(self.space))


def paper_plan() -> List[Tuple[int, int]]:
    """The 22-searchable-layer CIFAR plan mirroring FBNet's macro-arch
    (used by the `paper` config; not built by default — see DESIGN.md)."""
    plan = []
    stages = [(16, 1, 4), (24, 2, 4), (32, 2, 4), (64, 2, 4), (96, 1, 4), (160, 1, 2)]
    for cout, stride, reps in stages:
        for r in range(reps):
            plan.append((cout, stride if r == 0 else 1))
    return plan  # 22 layers


# ---------------------------------------------------------------------------
# Flat parameter layout. Rust reads this from manifest.json and owns
# initialization + optimization; python only defines names/shapes/offsets.
# Weights AND batch-norms are shared per (T, K) across the E dimension
# (channel masking); E only selects how many channels are alive.
# ---------------------------------------------------------------------------


def _he(fan_in: int) -> Dict[str, Any]:
    return {"kind": "he_normal", "fan_in": fan_in}


def _const(v: float) -> Dict[str, Any]:
    return {"kind": "const", "value": v}


def build_layout(cfg: SupernetConfig) -> List[Dict[str, Any]]:
    """Enumerate every parameter tensor: name, shape, offset, init, ltype,
    layer index (-1 for stem/head). ltype drives PGP gating in rust."""
    entries: List[Dict[str, Any]] = []
    off = 0

    def add(name, shape, init, ltype, layer):
        nonlocal off
        size = 1
        for d in shape:
            size *= d
        entries.append(
            {
                "name": name,
                "shape": list(shape),
                "offset": off,
                "size": size,
                "init": init,
                "ltype": ltype,
                "layer": layer,
            }
        )
        off += size

    # Stem: 3x3 conv stride 1 + BN
    add("stem/w", (3, 3, cfg.input_ch, cfg.stem_ch), _he(9 * cfg.input_ch), "common", -1)
    add("stem/bn/g", (cfg.stem_ch,), _const(1.0), "common", -1)
    add("stem/bn/b", (cfg.stem_ch,), _const(0.0), "common", -1)

    cin = cfg.stem_ch
    for l, (cout, stride) in enumerate(cfg.plan):
        mid_max = cin * E_MAX
        # The paper's customized recipe (Sec. 3.2, following BigNAS [27])
        # zero-inits the LAST BN gamma of each block — but only residual
        # blocks: a gamma_zero output on a non-residual (stride/channel-
        # changing) block would zero the whole signal path at init.
        residual = stride == 1 and cin == cout
        bn3_init = {"kind": "gamma_zero"} if residual else _const(1.0)
        for t in SPACE_TYPES[cfg.space]:
            for k in K_CHOICES:
                pre = f"L{l}/{t}/k{k}"
                add(f"{pre}/pw1", (cin, mid_max), _he(cin), t, l)
                add(f"{pre}/dw", (k, k, mid_max), _he(k * k), t, l)
                add(f"{pre}/pw2", (mid_max, cout), _he(mid_max), t, l)
                add(f"{pre}/bn1/g", (mid_max,), _const(1.0), t, l)
                add(f"{pre}/bn1/b", (mid_max,), _const(0.0), t, l)
                add(f"{pre}/bn2/g", (mid_max,), _const(1.0), t, l)
                add(f"{pre}/bn2/b", (mid_max,), _const(0.0), t, l)
                add(f"{pre}/bn3/g", (cout,), bn3_init, t, l)
                add(f"{pre}/bn3/b", (cout,), _const(0.0), t, l)
        cin = cout

    # Head: PW conv + BN + GAP + FC
    add("head/w", (cin, cfg.head_ch), _he(cin), "common", -1)
    add("head/bn/g", (cfg.head_ch,), _const(1.0), "common", -1)
    add("head/bn/b", (cfg.head_ch,), _const(0.0), "common", -1)
    add("fc/w", (cfg.head_ch, cfg.num_classes), _he(cfg.head_ch), "common", -1)
    add("fc/b", (cfg.num_classes,), _const(0.0), "common", -1)
    return entries


def n_params(layout: List[Dict[str, Any]]) -> int:
    last = layout[-1]
    return last["offset"] + last["size"]


class ParamView:
    """Slices tensors out of the flat parameter vector by layout name."""

    def __init__(self, layout: List[Dict[str, Any]], flat: jnp.ndarray):
        self._idx = {e["name"]: e for e in layout}
        self._flat = flat

    def __getitem__(self, name: str) -> jnp.ndarray:
        e = self._idx[name]
        return self._flat[e["offset"] : e["offset"] + e["size"]].reshape(e["shape"])


# ---------------------------------------------------------------------------
# Layer math
# ---------------------------------------------------------------------------


def _bn(x, g, b):
    return ref.batch_norm_ref(x, g, b)


def _pw(x2d: jnp.ndarray, w: jnp.ndarray, t: str, use_pallas: bool) -> jnp.ndarray:
    if use_pallas:
        return {"conv": conv_pw, "shift": shift_pw, "adder": adder_pw}[t](x2d, w)
    return {
        "conv": ref.conv_pw_ref,
        "shift": ref.shift_pw_ref,
        "adder": ref.adder_pw_ref,
    }[t](x2d, w)


def _pw_masked(x2d: jnp.ndarray, w: jnp.ndarray, t: str, kmask: jnp.ndarray):
    """Contraction with a soft channel mask on the reduction axis.

    conv/shift: masking the input is exact (0 * w == 0). adder: the mask
    must weight the |x - w| terms (see adder_pw_masked_ref).
    """
    if t == "adder":
        return ref.adder_pw_masked_ref(x2d, w, kmask)
    return _pw(x2d * kmask[None, :], w, t, use_pallas=False)


def _dw(x: jnp.ndarray, w: jnp.ndarray, stride: int, t: str, use_pallas: bool):
    if use_pallas:
        return dw_apply(x, w, stride=stride, mode=t)
    return {
        "conv": ref.dw_conv_ref,
        "shift": ref.dw_shift_ref,
        "adder": ref.dw_adder_ref,
    }[t](x, w, stride)


def _stem(x, pv: ParamView, stride: int = 2):
    w = pv["stem/w"]
    y = jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )
    return jax.nn.relu(_bn(y, pv["stem/bn/g"], pv["stem/bn/b"]))


def _skip_path(x, stride: int, cout: int):
    """Parameter-free skip: avg-pool for stride, zero-pad/slice channels."""
    if stride > 1:
        x = jax.lax.reduce_window(
            x, 0.0, jax.lax.add, (1, stride, stride, 1), (1, stride, stride, 1), "SAME"
        ) / float(stride * stride)
    cin = x.shape[-1]
    if cout > cin:
        x = jnp.pad(x, ((0, 0),) * 3 + ((0, cout - cin),))
    elif cout < cin:
        x = x[..., :cout]
    return x


def _quant_w(w, t, quant_bits):
    if quant_bits is None:
        return w
    bits = quant_bits.get(t, 8)
    return ref.fake_quant_ref(w, bits, jnp.max(jnp.abs(w)))


def _quant_a(a, quant_bits):
    if quant_bits is None:
        return a
    return ref.fake_quant_ref(a, quant_bits.get("act", 8), jnp.max(jnp.abs(a)))


def masked_block_apply(
    x: jnp.ndarray,
    pv: ParamView,
    l: int,
    t: str,
    k: int,
    kmask: jnp.ndarray,
    stride: int,
    cout: int,
    quant_bits: Optional[Dict[str, int]] = None,
) -> jnp.ndarray:
    """One (T, K) block at full width with a soft E channel mask
    (Fig. 3 right: PW -> BN/ReLU -> DW -> BN/ReLU -> PW -> BN, + residual
    when shape-preserving). kmask has mid_max entries in [0, 1]."""
    b, h, w_dim, cin = x.shape
    pre = f"L{l}/{t}/k{k}"
    w1 = _quant_w(pv[f"{pre}/pw1"], t, quant_bits)
    wd = _quant_w(pv[f"{pre}/dw"], t, quant_bits)
    w2 = _quant_w(pv[f"{pre}/pw2"], t, quant_bits)

    h1 = _pw(x.reshape(-1, cin), w1, t, use_pallas=False)
    mid = h1.shape[-1]
    h1 = h1.reshape(b, h, w_dim, mid)
    h1 = jax.nn.relu(_bn(h1, pv[f"{pre}/bn1/g"], pv[f"{pre}/bn1/b"])) * kmask
    h1 = _quant_a(h1, quant_bits)
    # DW is per-channel; masking the output kills dead channels exactly
    # (including adder-mode's nonzero response to zero input).
    h2 = _dw(h1, wd, stride, t, use_pallas=False) * kmask
    h2 = jax.nn.relu(_bn(h2, pv[f"{pre}/bn2/g"], pv[f"{pre}/bn2/b"])) * kmask
    h2 = _quant_a(h2, quant_bits)
    ho, wo = h2.shape[1], h2.shape[2]
    h3 = _pw_masked(h2.reshape(-1, mid), w2, t, kmask).reshape(b, ho, wo, cout)
    h3 = _bn(h3, pv[f"{pre}/bn3/g"], pv[f"{pre}/bn3/b"])
    if stride == 1 and cin == cout:
        h3 = h3 + x
    return _quant_a(h3, quant_bits)


def block_apply_exact(
    x: jnp.ndarray,
    pv: ParamView,
    l: int,
    cand: Dict[str, Any],
    stride: int,
    cout: int,
    use_pallas: bool,
) -> jnp.ndarray:
    """Exact E-sliced candidate block (the fixed-child path): weights and
    shared (T,K) BN params sliced to the first cin*E channels. Equal to
    masked_block_apply with a one-hot mask; pytest asserts this."""
    if cand["t"] == "skip":
        return _skip_path(x, stride, cout)
    t, e, k = cand["t"], cand["e"], cand["k"]
    b, h, w_dim, cin = x.shape
    mid = cin * e
    pre = f"L{l}/{t}/k{k}"
    w1 = pv[f"{pre}/pw1"][:, :mid]
    wd = pv[f"{pre}/dw"][:, :, :mid]
    w2 = pv[f"{pre}/pw2"][:mid, :]

    h1 = _pw(x.reshape(-1, cin), w1, t, use_pallas).reshape(b, h, w_dim, mid)
    h1 = jax.nn.relu(_bn(h1, pv[f"{pre}/bn1/g"][:mid], pv[f"{pre}/bn1/b"][:mid]))
    h2 = _dw(h1, wd, stride, t, use_pallas)
    h2 = jax.nn.relu(_bn(h2, pv[f"{pre}/bn2/g"][:mid], pv[f"{pre}/bn2/b"][:mid]))
    ho, wo = h2.shape[1], h2.shape[2]
    h3 = _pw(h2.reshape(-1, mid), w2, t, use_pallas).reshape(b, ho, wo, cout)
    h3 = _bn(h3, pv[f"{pre}/bn3/g"], pv[f"{pre}/bn3/b"])
    if stride == 1 and cin == cout:
        h3 = h3 + x
    return h3


def _head(x, pv: ParamView):
    b = x.shape[0]
    cin = x.shape[-1]
    y = ref.conv_pw_ref(x.reshape(-1, cin), pv["head/w"]).reshape(
        b, x.shape[1], x.shape[2], -1
    )
    y = jax.nn.relu(_bn(y, pv["head/bn/g"], pv["head/bn/b"]))
    y = jnp.mean(y, axis=(1, 2))  # GAP
    return y @ pv["fc/w"] + pv["fc/b"]


# ---------------------------------------------------------------------------
# Supernet forward with Gumbel-Softmax mixing (Eqs. 6-7)
# ---------------------------------------------------------------------------

NEG_BIG = -1e9
EPS = 1e-8


def gumbel_softmax_weights(alpha, gumbel, mask, tau):
    """gs_li = softmax_i((masked alpha + gumbel) / tau) per layer (Eq. 7).

    mask in {0,1}: 0 kills a candidate (top-k masking of Eq. 6 and/or a PGP
    stage gate). Masked logits go to -inf so their weight is exactly 0.
    """
    keep = mask > 0.5
    logits = jnp.where(keep, alpha + gumbel, NEG_BIG)
    return jax.nn.softmax(logits / tau, axis=-1)


def _e_mask(cin: int, e: int) -> jnp.ndarray:
    """Channel indicator for expansion e at base width cin."""
    m = jnp.zeros((cin * E_MAX,), jnp.float32)
    return m.at[: cin * e].set(1.0)


def supernet_forward(
    cfg: SupernetConfig,
    flat: jnp.ndarray,
    alpha: jnp.ndarray,
    gumbel: jnp.ndarray,
    mask: jnp.ndarray,
    tau: jnp.ndarray,
    x: jnp.ndarray,
    quant_bits: Optional[Dict[str, int]] = None,
):
    """Returns (logits [B, classes], gs [L, n_cand]).

    Per layer, candidates sharing (T, K) are computed as ONE full-width
    block whose E choice enters as the gs-weighted channel mask
    (FBNetV2-style); Skip is mixed in with its own gs weight.
    """
    layout = build_layout(cfg)
    pv = ParamView(layout, flat)
    cands = candidates(cfg.space)
    gs = gumbel_softmax_weights(alpha, gumbel, mask, tau)
    h = _stem(x, pv, cfg.stem_stride)
    cin = cfg.stem_ch
    for l, (cout, stride) in enumerate(cfg.plan):
        outs = []
        for t in SPACE_TYPES[cfg.space]:
            for k in K_CHOICES:
                idxs = [
                    (ci, c["e"])
                    for ci, c in enumerate(cands)
                    if c.get("t") == t and c.get("k") == k
                ]
                g_sum = sum(gs[l, ci] for ci, _ in idxs)
                kmask = sum(
                    (gs[l, ci] / (g_sum + EPS)) * _e_mask(cin, e) for ci, e in idxs
                )
                y = masked_block_apply(
                    h, pv, l, t, k, kmask, stride, cout, quant_bits
                )
                outs.append(g_sum * y)
        skip_ci = len(cands) - 1
        outs.append(gs[l, skip_ci] * _skip_path(h, stride, cout))
        h = jax.nn.relu(sum(outs[1:], outs[0]))
        cin = cout
    return _head(h, pv), gs


def _ce_and_acc(logits, labels, num_classes):
    onehot = jax.nn.one_hot(labels, num_classes)
    logp = jax.nn.log_softmax(logits)
    ce = -jnp.mean(jnp.sum(onehot * logp, axis=-1))
    ncorrect = jnp.sum(
        (jnp.argmax(logits, axis=-1) == labels.astype(jnp.int32)).astype(jnp.float32)
    )
    return ce, ncorrect


def supernet_loss(cfg, flat, alpha, gumbel, mask, tau, lam, cost, x, labels):
    """Eq. 5: CE + lambda * E_gs[hardware cost]. Returns aux scalars too."""
    logits, gs = supernet_forward(cfg, flat, alpha, gumbel, mask, tau, x)
    ce, ncorrect = _ce_and_acc(logits, labels, cfg.num_classes)
    hw = jnp.sum(gs * cost)
    return ce + lam * hw, (ce, hw, ncorrect)


def make_step_fn(cfg: SupernetConfig):
    """The AOT training-step entry point: returns loss scalars + grads
    w.r.t. (flat params, alpha). The rust coordinator applies the
    optimizers (SGDM for w, Adam for alpha) and all masking."""

    def step(flat, alpha, gumbel, mask, tau, lam, cost, x, labels):
        (loss, (ce, hw, ncorrect)), (dflat, dalpha) = jax.value_and_grad(
            lambda f, a: supernet_loss(
                cfg, f, a, gumbel, mask, tau, lam, cost, x, labels
            ),
            argnums=(0, 1),
            has_aux=True,
        )(flat, alpha)
        return loss, ce, hw, ncorrect, dflat, dalpha

    return step


def make_eval_fn(cfg: SupernetConfig, quant: bool = False):
    """AOT eval entry point (deterministic: no gumbel noise). With
    quant=True applies the paper's FXP8 (FXP6 for shift/adder) setting."""
    qb = {"conv": 8, "shift": 6, "adder": 6, "act": 8} if quant else None

    def evalf(flat, alpha, mask, tau, x, labels):
        zeros = jnp.zeros_like(alpha)
        logits, _ = supernet_forward(
            cfg, flat, alpha, zeros, mask, tau, x, quant_bits=qb
        )
        ce, ncorrect = _ce_and_acc(logits, labels, cfg.num_classes)
        return ce, ncorrect, logits

    return evalf


# ---------------------------------------------------------------------------
# Fixed representative child (L1 Pallas kernels on the executed path)
# ---------------------------------------------------------------------------

# A hand-picked hybrid-all architecture exercising all three operator types
# and both kernel sizes; used by the rust serving-style benches and the
# pallas-vs-jnp cross-check through PJRT.
FIXED_CHILD: List[Dict[str, Any]] = [
    {"t": "conv", "e": 3, "k": 3},
    {"t": "shift", "e": 3, "k": 3},
    {"t": "adder", "e": 3, "k": 5},
    {"t": "conv", "e": 6, "k": 5},
    {"t": "shift", "e": 1, "k": 3},
    {"t": "adder", "e": 6, "k": 3},
]


def child_cand_indices(cfg: SupernetConfig, arch: List[Dict[str, Any]]) -> List[int]:
    cands = candidates(cfg.space)
    idx = []
    for a in arch:
        match = [i for i, c in enumerate(cands) if c == a]
        assert match, f"arch entry {a} not in space {cfg.space}"
        idx.append(match[0])
    return idx


def make_child_infer_fn(
    cfg: SupernetConfig, arch: List[Dict[str, Any]], use_pallas: bool
):
    """Standalone child forward: computes ONLY the chosen candidate per
    layer (unlike one-hot supernet eval, which computes all blocks)."""

    def infer(flat, x):
        layout = build_layout(cfg)
        pv = ParamView(layout, flat)
        h = _stem(x, pv, cfg.stem_stride)
        for l, (cout, stride) in enumerate(cfg.plan):
            h = block_apply_exact(h, pv, l, arch[l], stride, cout, use_pallas)
            h = jax.nn.relu(h)
        return _head(h, pv)

    return infer
