"""AOT compile path: lower every NASA entry point to HLO *text* + manifest.

Run once via `make artifacts`; python never runs on the rust request path.

Interchange format is HLO text, NOT `lowered.compiler_ir("hlo").serialize()`:
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the `xla`
crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the HLO text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).

Artifacts (fast config, default):
  supernet_step_{space}_{ds}.hlo.txt        training step: loss + grads
  supernet_eval_{space}_{ds}.hlo.txt        deterministic eval (FP32)
  supernet_eval_quant_{space}_{ds}.hlo.txt  FXP8/FXP6 fake-quant eval
  child_infer_pallas.hlo.txt                fixed child, Pallas kernels
  child_infer_jnp.hlo.txt                   fixed child, jnp ops
  kernel_{conv_pw,shift_pw,adder_pw,dw_conv}.hlo.txt   L1 micro artifacts
  fig2b_ps_toy.json                         DeepShift-PS collapse toy data
  manifest.json                             shapes + layouts + candidates

The manifest is the single source of truth the rust side reads for
parameter layouts, candidate enumeration and artifact I/O shapes.
"""

from __future__ import annotations

import argparse
import json
import os
import time
from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_to_file(fn, example_args, path: str) -> Dict[str, Any]:
    t0 = time.time()
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    dt = time.time() - t0
    print(f"  wrote {path} ({len(text)/1e6:.2f} MB, {dt:.1f}s)")
    return {
        "path": os.path.basename(path),
        "inputs": [
            {"shape": list(a.shape), "dtype": str(a.dtype)} for a in example_args
        ],
    }


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def supernet_specs(cfg: M.SupernetConfig):
    """Input specs for (step, eval) entry points, in argument order."""
    L, NC, B, H = cfg.n_layers, cfg.n_cand, cfg.batch, cfg.input_hw
    P = M.n_params(M.build_layout(cfg))
    step = [
        spec((P,)),  # flat params
        spec((L, NC)),  # alpha
        spec((L, NC)),  # gumbel noise
        spec((L, NC)),  # mask (top-k & PGP gate)
        spec(()),  # tau
        spec(()),  # lambda
        spec((L, NC)),  # hw cost table
        spec((B, H, H, cfg.input_ch)),  # x
        spec((B,), I32),  # labels
    ]
    evalf = [
        spec((P,)),
        spec((L, NC)),
        spec((L, NC)),
        spec(()),
        spec((B, H, H, cfg.input_ch)),
        spec((B,), I32),
    ]
    return step, evalf


def layout_json(cfg: M.SupernetConfig) -> Dict[str, Any]:
    layout = M.build_layout(cfg)
    cands = M.candidates(cfg.space)
    # Per-layer geometry for the rust hw-cost table / op counting.
    layers = []
    h = cfg.input_hw
    cin = cfg.stem_ch
    for cout, stride in cfg.plan:
        ho = -(-h // stride)
        layers.append(
            {
                "cin": cin,
                "cout": cout,
                "h_in": h,
                "w_in": h,
                "h_out": ho,
                "w_out": ho,
                "stride": stride,
            }
        )
        h, cin = ho, cout
    return {
        "space": cfg.space,
        "n_layers": cfg.n_layers,
        "n_cand": cfg.n_cand,
        "cands": cands,
        "layers": layers,
        "n_params": M.n_params(layout),
        "param_layout": layout,
        "stem": {"ch": cfg.stem_ch, "k": 3},
        "head": {"ch": cfg.head_ch},
        "num_classes": cfg.num_classes,
        "batch": cfg.batch,
        "input_hw": cfg.input_hw,
        "input_ch": cfg.input_ch,
    }


def build_fig2b_ps_toy(out_dir: str) -> None:
    """Toy reproduction of Fig. 2(b): train a DeepShift-PS layer and a
    DeepShift-Q layer side by side inside a hybrid (conv + shift) net on a
    small regression; record the realized W_shift histograms.

    PS parameterizes (s, p) directly; because round(p) only changes when p
    crosses integer boundaries and the straight-through gradient keeps
    pushing |p| up for small targets, the realized weights s*2^p collapse
    toward 0/degenerate values when mixed with conv layers whose weights
    are small (|w| << 1). Q re-quantizes a healthy latent conv weight each
    step and stays matched to the conv distribution (Fig. 2c).
    """
    rng = np.random.default_rng(0)
    din, dout, n = 32, 32, 512
    x = jnp.asarray(rng.normal(size=(n, din)).astype(np.float32))
    w_true = jnp.asarray((rng.normal(size=(din, dout)) * 0.1).astype(np.float32))
    y = x @ w_true

    def ste(f, w):  # straight-through: forward f(w), backward identity
        return w + jax.lax.stop_gradient(f(w) - w)

    # --- PS: optimize s, p directly (Eq. 2) ---
    s = jnp.asarray(rng.normal(size=(din, dout)).astype(np.float32))
    p = jnp.asarray((rng.normal(size=(din, dout)) - 4.0).astype(np.float32))

    def ps_loss(s, p):
        w = ste(lambda v: jnp.clip(jnp.round(v), -1, 1), s) * 2.0 ** ste(
            lambda v: jnp.clip(jnp.round(v), M.ref.P_MIN, M.ref.P_MAX), p
        )
        return jnp.mean((x @ w - y) ** 2)

    ps_grad = jax.jit(jax.grad(ps_loss, argnums=(0, 1)))
    for _ in range(200):
        gs_, gp_ = ps_grad(s, p)
        s, p = s - 0.05 * gs_, p - 0.05 * gp_
    w_ps = np.asarray(M.ref.ps_construct(s, p))

    # --- Q: optimize latent w*, quantize each forward (Eq. 3) ---
    wq = jnp.asarray((rng.normal(size=(din, dout)) * 0.1).astype(np.float32))

    def q_loss(w):
        return jnp.mean((x @ ste(M.ref.pow2_quant, w) - y) ** 2)

    q_grad = jax.jit(jax.grad(q_loss))
    for _ in range(200):
        wq = wq - 0.05 * q_grad(wq)
    w_q = np.asarray(M.ref.pow2_quant(wq))

    def hist(a):
        h, edges = np.histogram(a.ravel(), bins=41, range=(-1.0, 1.0))
        return {"counts": h.tolist(), "edges": edges.tolist()}

    data = {
        "ps": hist(w_ps),
        "q": hist(w_q),
        "ps_frac_zero": float(np.mean(np.abs(w_ps) < 1e-6)),
        "q_frac_zero": float(np.mean(np.abs(w_q) < 1e-6)),
        "ps_mean_abs": float(np.mean(np.abs(w_ps))),
        "q_mean_abs": float(np.mean(np.abs(w_q))),
    }
    with open(os.path.join(out_dir, "fig2b_ps_toy.json"), "w") as f:
        json.dump(data, f)
    print(
        f"  fig2b toy: PS zero-frac={data['ps_frac_zero']:.2f} "
        f"Q zero-frac={data['q_frac_zero']:.2f}"
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument(
        "--spaces",
        default="conv_only,hybrid_shift,hybrid_adder,hybrid_all",
        help="comma-separated search spaces to lower",
    )
    ap.add_argument(
        "--datasets",
        default="c10,c100",
        help="c10 (10 classes) and/or c100 (100 classes)",
    )
    ap.add_argument("--skip-child", action="store_true")
    ap.add_argument("--skip-kernels", action="store_true")
    args = ap.parse_args()
    out = args.out
    os.makedirs(out, exist_ok=True)

    manifest: Dict[str, Any] = {"supernets": {}, "kernels": {}, "fixed_child": {}}

    classes = {"c10": 10, "c100": 100}
    for ds in args.datasets.split(","):
        for space in args.spaces.split(","):
            cfg = M.SupernetConfig(space=space, num_classes=classes[ds])
            key = f"{space}_{ds}"
            print(f"[supernet {key}] n_params={M.n_params(M.build_layout(cfg))}")
            step_specs, eval_specs = supernet_specs(cfg)
            ents: Dict[str, Any] = {"layout": layout_json(cfg)}
            ents["step"] = lower_to_file(
                M.make_step_fn(cfg), step_specs, f"{out}/supernet_step_{key}.hlo.txt"
            )
            ents["eval"] = lower_to_file(
                M.make_eval_fn(cfg, quant=False),
                eval_specs,
                f"{out}/supernet_eval_{key}.hlo.txt",
            )
            ents["eval_quant"] = lower_to_file(
                M.make_eval_fn(cfg, quant=True),
                eval_specs,
                f"{out}/supernet_eval_quant_{key}.hlo.txt",
            )
            manifest["supernets"][key] = ents

    if not args.skip_child:
        cfg = M.SupernetConfig(space="hybrid_all", num_classes=10)
        P = M.n_params(M.build_layout(cfg))
        B, H = cfg.batch, cfg.input_hw
        child_specs = [spec((P,)), spec((B, H, H, cfg.input_ch))]
        print("[fixed child]")
        manifest["fixed_child"] = {
            "arch": M.FIXED_CHILD,
            "space_key": "hybrid_all_c10",
            "cand_indices": M.child_cand_indices(cfg, M.FIXED_CHILD),
            "pallas": lower_to_file(
                M.make_child_infer_fn(cfg, M.FIXED_CHILD, use_pallas=True),
                child_specs,
                f"{out}/child_infer_pallas.hlo.txt",
            ),
            "jnp": lower_to_file(
                M.make_child_infer_fn(cfg, M.FIXED_CHILD, use_pallas=False),
                child_specs,
                f"{out}/child_infer_jnp.hlo.txt",
            ),
        }

    if not args.skip_kernels:
        from .kernels import adder_pw, conv_pw, dw_apply, shift_pw

        print("[kernel micro artifacts]")
        m, k, n = 64, 48, 32
        pw_specs = [spec((m, k)), spec((k, n))]
        manifest["kernels"]["conv_pw"] = lower_to_file(
            lambda x, w: (conv_pw(x, w),), pw_specs, f"{out}/kernel_conv_pw.hlo.txt"
        )
        manifest["kernels"]["shift_pw"] = lower_to_file(
            lambda x, w: (shift_pw(x, w),), pw_specs, f"{out}/kernel_shift_pw.hlo.txt"
        )
        manifest["kernels"]["adder_pw"] = lower_to_file(
            lambda x, w: (adder_pw(x, w),), pw_specs, f"{out}/kernel_adder_pw.hlo.txt"
        )
        dw_specs = [spec((4, 12, 12, 16)), spec((3, 3, 16))]
        manifest["kernels"]["dw_conv"] = lower_to_file(
            lambda x, w: (dw_apply(x, w, stride=1, mode="adder"),),
            dw_specs,
            f"{out}/kernel_dw_conv.hlo.txt",
        )

    build_fig2b_ps_toy(out)

    with open(f"{out}/manifest.json", "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest -> {out}/manifest.json")


if __name__ == "__main__":
    main()
