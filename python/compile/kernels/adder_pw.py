"""Pallas kernel: AdderNet pointwise layer — negative l1 distance (Eq. 4).

The ALP chunk's workload: Y[m,n] = -sum_k |x[m,k] - w[k,n]|. There is no
multiplication anywhere in this kernel — it is broadcast-subtract /
abs / reduce, i.e. pure adder/comparator work, which is exactly the
algorithmic property the paper's Adder Units exploit (an 8-bit adder is
~3-5x cheaper than an 8-bit multiplier at 45nm).

Kernel-roofline:
  * This is VPU (vector) work on TPU, not MXU: arithmetic intensity is
    3 ops (sub, abs, add) per element-pair versus the MXU's 2-flops/pair
    fused MAC, and there is no systolic reuse — the TPU rethink (DESIGN.md
    §Hardware-Adaptation) tiles it so each [bm, K] activation tile stays
    VMEM-resident while sweeping bn weight columns (input-stationary).
  * Block shapes: x [bm, K], w [K, bn]; scratch accumulator [bm, bn].
    Inner loop over K in chunks of kc=8 keeps the broadcast tensor
    [bm, kc, bn] bounded: 64*8*128*4 = 256 KiB VMEM at the default tiles.
  * Grid: (M/bm, N/bn) output-stationary like conv_pw, so partial l1 sums
    never spill to HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .tiling import cdiv, pad_to, pick_block


def _adder_kernel(x_ref, w_ref, o_ref, *, kc: int):
    x = x_ref[...]  # [bm, K]
    w = w_ref[...]  # [K, bn]
    k = x.shape[1]
    acc = jnp.zeros((x.shape[0], w.shape[1]), jnp.float32)
    # Chunked reduction over the contraction dim bounds the broadcast
    # intermediate to [bm, kc, bn] (VMEM scratch), cf. header analysis.
    for k0 in range(0, k, kc):
        xs = x[:, k0 : k0 + kc]  # [bm, kc]
        ws = w[k0 : k0 + kc, :]  # [kc, bn]
        acc = acc + jnp.sum(jnp.abs(xs[:, :, None] - ws[None, :, :]), axis=1)
    o_ref[...] = -acc


@functools.partial(jax.jit, static_argnames=("bm", "bn", "kc"))
def adder_pw(
    x2d: jnp.ndarray, w: jnp.ndarray, bm: int = 64, bn: int = 128, kc: int = 8
):
    """Adder pointwise layer: x2d [M, Cin], w [Cin, Cout] -> [M, Cout].

    Zero-padding is correctness-preserving here because BOTH operands pad
    with zeros on the contraction axis: |0 - 0| = 0 contributes nothing.
    """
    m, k = x2d.shape
    k2, n = w.shape
    assert k == k2
    bm = pick_block(m, bm)
    bn = pick_block(n, bn)
    xp = pad_to(x2d, 0, bm)
    wp = pad_to(w, 1, bn)
    mp, np_ = xp.shape[0], wp.shape[1]
    kernel = functools.partial(_adder_kernel, kc=kc)
    out = pl.pallas_call(
        kernel,
        grid=(cdiv(mp, bm), cdiv(np_, bn)),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp)
    return out[:m, :n]
