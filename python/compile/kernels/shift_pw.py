"""Pallas kernel: DeepShift-Q pointwise layer (fused pow2-quant + matmul).

The SLP chunk's workload: every weight is sign*2^p (Eq. 3), so on shift
hardware each "product" is a bitwise shift. The kernel fuses the
quantization into the tile load so the latent float weight w* never leaves
VMEM unquantized — mirroring how the paper's SLP reads 6-bit (sign, p)
codes from its RFs rather than full-precision weights.

Kernel-roofline:
  * Same tiling as conv_pw ([bm,K]x[K,bn] output-stationary tiles); the
    quantization adds 4 VPU ops per weight element, amortized across the bm
    rows that reuse the quantized tile (weight-stationary within a block).
  * On TPU the quantized matmul still uses the MXU; the paper's point is an
    ASIC one (shifters are ~5x cheaper than multipliers at 45nm) — that
    economics lives in the L3 accelerator model (accel/pe.rs), while this
    kernel preserves the exact arithmetic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import P_MAX, P_MIN
from .tiling import LANE, cdiv, pad_to, pick_block


def _shift_matmul_kernel(x_ref, w_ref, o_ref):
    w = w_ref[...]
    # DeepShift-Q (Eq. 3), fused at the tile level.
    eps = 1e-12
    s = jnp.sign(w)
    p = jnp.clip(jnp.round(jnp.log2(jnp.abs(w) + eps)), P_MIN, P_MAX)
    wq = jnp.where(jnp.abs(w) < 2.0 ** (P_MIN - 1), 0.0, s * 2.0**p)
    o_ref[...] = jnp.dot(x_ref[...], wq, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def shift_pw(x2d: jnp.ndarray, w: jnp.ndarray, bm: int = 128, bn: int = LANE):
    """DeepShift-Q pointwise layer: x2d [M, Cin], w [Cin, Cout] (latent)."""
    m, k = x2d.shape
    k2, n = w.shape
    assert k == k2
    bm = pick_block(m, bm)
    bn = pick_block(n, bn)
    xp = pad_to(x2d, 0, bm)
    wp = pad_to(w, 1, bn)
    mp, np_ = xp.shape[0], wp.shape[1]
    out = pl.pallas_call(
        _shift_matmul_kernel,
        grid=(cdiv(mp, bm), cdiv(np_, bn)),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp)
    return out[:m, :n]
