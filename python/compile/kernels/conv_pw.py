"""Pallas kernel: pointwise (1x1) convolution as a tiled matmul.

This is the multiplication-based operator of the hybrid search space — the
CLP chunk's workload in the NASA accelerator.

Kernel-roofline (L1 estimate, recorded per DESIGN.md §Kernel-roofline):
  * Block shapes: x [bm, K] in VMEM, w [K, bn] in VMEM, out [bm, bn].
    With bm=128, bn=128, K<=256 (our channel sizes), VMEM footprint is
    128*256*4 + 256*128*4 + 128*128*4 = 320 KiB  << 16 MiB VMEM.
  * MXU: the inner jnp.dot maps to 128x128 systolic passes; with
    K un-tiled the kernel performs ceil(K/128) MXU passes per block and is
    compute-bound once M*N >= 128^2 (arithmetic intensity 2*K flops per
    4*(K+K+1) bytes moved per output row/col pair).
  * Grid: (M/bm, N/bn); each program owns one output tile => no revisits of
    HBM for partial sums (output-stationary schedule, cf. the paper's OS
    dataflow choice for CLP).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .tiling import LANE, cdiv, pad_to, pick_block


def _matmul_kernel(x_ref, w_ref, o_ref):
    # One [bm, K] x [K, bn] tile product per program instance.
    o_ref[...] = jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def conv_pw(x2d: jnp.ndarray, w: jnp.ndarray, bm: int = 128, bn: int = LANE):
    """Pointwise conv: x2d [M, Cin] @ w [Cin, Cout] -> [M, Cout]."""
    m, k = x2d.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bm = pick_block(m, bm)
    bn = pick_block(n, bn)
    xp = pad_to(x2d, 0, bm)
    wp = pad_to(w, 1, bn)
    mp, np_ = xp.shape[0], wp.shape[1]
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(cdiv(mp, bm), cdiv(np_, bn)),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,  # CPU-PJRT cannot run Mosaic custom-calls
    )(xp, wp)
    return out[:m, :n]
