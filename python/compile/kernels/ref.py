"""Pure-jnp reference oracles for NASA's hybrid operators (L1 correctness).

These are the ground-truth semantics for the three operator families the
paper mixes in its hybrid search spaces (Sec. 3.1):

  * convolutions          — multiplication-based cross-correlation,
  * shift layers          — DeepShift [6]: weights constrained to sign*2^p.
                            Two constructions: PS (train s, p directly; the
                            paper shows it collapses in hybrid nets, Fig. 2b)
                            and Q (quantize a latent conv weight w* to the
                            nearest power of two, Eq. 3 — what NASA uses),
  * adder layers          — AdderNet [20]: negative l1 distance between the
                            input patch and the weight (Eq. 4).

Every Pallas kernel in this package is pytest-checked against these
functions (assert_allclose), and the AOT-lowered HLO executed from rust is
integration-checked against the same numbers.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# DeepShift weight constructions (Eq. 2 and Eq. 3 of the paper)
# ---------------------------------------------------------------------------

# Shift exponents are clipped to a small signed range, mirroring the paper's
# 6-bit shift-layer quantization (sign + 5-bit exponent field in spirit).
P_MIN, P_MAX = -14.0, 0.0


def pow2_quant(w: jnp.ndarray) -> jnp.ndarray:
    """DeepShift-Q (Eq. 3): w_shift = sign(w*) * 2^round(log2|w*|).

    Zero weights stay zero. Exponents clip to [P_MIN, P_MAX] so the result
    is representable in a small shift field (the paper quantizes shift
    layers to 6 bits).
    """
    eps = 1e-12
    s = jnp.sign(w)
    p = jnp.round(jnp.log2(jnp.abs(w) + eps))
    p = jnp.clip(p, P_MIN, P_MAX)
    return jnp.where(jnp.abs(w) < 2.0 ** (P_MIN - 1), 0.0, s * 2.0**p)


def ps_construct(s: jnp.ndarray, p: jnp.ndarray) -> jnp.ndarray:
    """DeepShift-PS (Eq. 2): W_shift = s * 2^p with s in [-1, 0, 1], p int.

    `s` is ternarized by rounding+clipping, `p` rounded to an integer. This
    is the construction that Fig. 2(b) shows collapsing to ~0 in hybrid
    nets; it exists here for the Fig. 2 reproduction.
    """
    s_q = jnp.clip(jnp.round(s), -1.0, 1.0)
    p_q = jnp.clip(jnp.round(p), P_MIN, P_MAX)
    return s_q * 2.0**p_q


# ---------------------------------------------------------------------------
# Pointwise (1x1) layer references. x2d: [M, Cin] (M = B*H*W), w: [Cin, Cout]
# ---------------------------------------------------------------------------


def conv_pw_ref(x2d: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Multiplication-based pointwise conv == plain matmul."""
    return x2d @ w


def shift_pw_ref(x2d: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """DeepShift-Q pointwise layer: matmul against pow2-quantized weights.

    On real shift hardware every product x * (s*2^p) is a bitwise shift of x
    by p plus a sign flip — multiplication-free. Numerically it is exactly
    this matmul.
    """
    return x2d @ pow2_quant(w)


def adder_pw_ref(x2d: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """AdderNet pointwise layer (Eq. 4): Y[m,n] = -sum_k |x[m,k] - w[k,n]|."""
    # [M, 1, Cin] - [1, Cout, Cin] -> [M, Cout, Cin]
    diff = x2d[:, None, :] - w.T[None, :, :]
    return -jnp.sum(jnp.abs(diff), axis=-1)


def adder_pw_masked_ref(
    x2d: jnp.ndarray, w: jnp.ndarray, kmask: jnp.ndarray
) -> jnp.ndarray:
    """Adder pointwise layer with a soft contraction-channel mask:
    Y[m,n] = -sum_k kmask[k] * |x[m,k] - w[k,n]|.

    Used by the FBNetV2-style channel-masked supernet (DESIGN.md): unlike
    conv/shift, masking an adder layer's input with zeros does NOT remove
    the masked channels' contribution (|0 - w| != 0), so the mask must
    enter the contraction itself. kmask == slicing indicator reproduces
    the exact E-sliced adder layer.
    """
    diff = jnp.abs(x2d[:, None, :] - w.T[None, :, :])  # [M, Cout, Cin]
    return -jnp.einsum("mnk,k->mn", diff, kmask)


# ---------------------------------------------------------------------------
# Depthwise KxK layer references. x: [B, H, W, C] (NHWC), w: [K, K, C]
# ---------------------------------------------------------------------------


def _dw_patches(x: jnp.ndarray, k: int, stride: int) -> jnp.ndarray:
    """Extract depthwise patches -> [B, Ho, Wo, K, K, C] with SAME padding.

    Uses lax.slice with native strides: strided *basic indexing* would
    lower to gather (and its VJP to scatter), which blows up both compile
    time and runtime on the PJRT CPU backend this project AOT-targets.
    """
    b, h, w_, c = x.shape
    pad = (k - 1) // 2
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    hp, wp = h + 2 * pad, w_ + 2 * pad
    ho = (hp - k) // stride + 1
    wo = (wp - k) // stride + 1
    rows = []
    for i in range(k):
        cols = []
        for j in range(k):
            sl = jax.lax.slice(
                xp,
                (0, i, j, 0),
                (b, i + (ho - 1) * stride + 1, j + (wo - 1) * stride + 1, c),
                (1, stride, stride, 1),
            )
            cols.append(sl)
        rows.append(jnp.stack(cols, axis=3))  # [B,Ho,Wo,K,C]
    return jnp.stack(rows, axis=3)  # [B,Ho,Wo,K,K,C]


def dw_conv_ref(x: jnp.ndarray, w: jnp.ndarray, stride: int = 1) -> jnp.ndarray:
    """Depthwise convolution (multiplication-based), SAME padding."""
    patches = _dw_patches(x, w.shape[0], stride)  # [B,Ho,Wo,K,K,C]
    return jnp.einsum("bhwijc,ijc->bhwc", patches, w)


def dw_shift_ref(x: jnp.ndarray, w: jnp.ndarray, stride: int = 1) -> jnp.ndarray:
    """Depthwise DeepShift-Q layer: depthwise conv with pow2 weights."""
    return dw_conv_ref(x, pow2_quant(w), stride)


def dw_adder_ref(x: jnp.ndarray, w: jnp.ndarray, stride: int = 1) -> jnp.ndarray:
    """Depthwise adder layer: Y[b,h,w,c] = -sum_ij |patch[i,j,c] - w[i,j,c]|."""
    patches = _dw_patches(x, w.shape[0], stride)  # [B,Ho,Wo,K,K,C]
    return -jnp.sum(jnp.abs(patches - w[None, None, None]), axis=(3, 4))


# ---------------------------------------------------------------------------
# Misc shared pieces (used by model.py and tested against known values)
# ---------------------------------------------------------------------------


def batch_norm_ref(
    x: jnp.ndarray, gamma: jnp.ndarray, beta: jnp.ndarray, eps: float = 1e-5
) -> jnp.ndarray:
    """Batch-statistics BN over all axes except the last (channel) axis.

    The supernet uses batch-stats normalization in both train and eval (no
    running averages) — deterministic for the fixed-batch synthetic
    workloads used in this reproduction; see DESIGN.md substitutions.
    """
    axes = tuple(range(x.ndim - 1))
    mu = jnp.mean(x, axis=axes, keepdims=True)
    var = jnp.var(x, axis=axes, keepdims=True)
    return gamma * (x - mu) * jax.lax.rsqrt(var + eps) + beta


def fake_quant_ref(x: jnp.ndarray, bits: int, scale: jnp.ndarray) -> jnp.ndarray:
    """Symmetric uniform fake-quantization to `bits` (Banner et al. style).

    q = clip(round(x / s_q), -qmax, qmax) * s_q with s_q = scale / qmax.
    """
    qmax = 2.0 ** (bits - 1) - 1.0
    s = jnp.maximum(scale, 1e-12) / qmax
    return jnp.clip(jnp.round(x / s), -qmax, qmax) * s
