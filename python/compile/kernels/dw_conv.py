"""Pallas kernel: depthwise KxK layer in all three operator flavours.

Depthwise layers dominate the DW stage of every candidate block
(PW -> DW -> PW, Fig. 3 right). The patch extraction (SAME padding +
stride) is done once in jnp — it is pure data movement that XLA fuses —
and the Pallas kernel performs the per-channel reduction over the K*K
window in the requested flavour:

  mode="conv"  : sum_ij patch[i,j,c] * w[i,j,c]          (MAC work, CLP)
  mode="shift" : sum_ij patch[i,j,c] * pow2(w[i,j,c])    (shift work, SLP)
  mode="adder" : -sum_ij |patch[i,j,c] - w[i,j,c]|       (adder work, ALP)

Kernel-roofline:
  * Input tile [bm, KK, bc] + weight [KK, bc] in VMEM; KK<=25, so with
    bm=128, bc=128 the footprint is 128*25*128*4 = 1.6 MiB — VMEM-resident.
  * Depthwise work is VPU-bound on TPU (no contraction across channels =>
    no MXU); the schedule is output-stationary over (M, C) tiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import P_MAX, P_MIN, _dw_patches
from .tiling import cdiv, pad_to, pick_block


def _dw_kernel(p_ref, w_ref, o_ref, *, mode: str):
    patch = p_ref[...]  # [bm, KK, bc]
    w = w_ref[...]  # [KK, bc]
    if mode == "conv":
        o_ref[...] = jnp.sum(patch * w[None], axis=1)
    elif mode == "shift":
        eps = 1e-12
        s = jnp.sign(w)
        p = jnp.clip(jnp.round(jnp.log2(jnp.abs(w) + eps)), P_MIN, P_MAX)
        wq = jnp.where(jnp.abs(w) < 2.0 ** (P_MIN - 1), 0.0, s * 2.0**p)
        o_ref[...] = jnp.sum(patch * wq[None], axis=1)
    elif mode == "adder":
        o_ref[...] = -jnp.sum(jnp.abs(patch - w[None]), axis=1)
    else:  # pragma: no cover
        raise ValueError(mode)


@functools.partial(jax.jit, static_argnames=("stride", "mode", "bm", "bc"))
def dw_apply(
    x: jnp.ndarray,
    w: jnp.ndarray,
    stride: int = 1,
    mode: str = "conv",
    bm: int = 128,
    bc: int = 128,
):
    """Depthwise layer: x [B,H,W,C] NHWC, w [K,K,C] -> [B,Ho,Wo,C].

    Adder-mode padding note: channels pad with zeros on BOTH patch and
    weight (|0-0| = 0), and the padded output channels are sliced away, so
    zero-padding is correctness-preserving in every mode.
    """
    b, h, w_dim, c = x.shape
    k = w.shape[0]
    patches = _dw_patches(x, k, stride)  # [B,Ho,Wo,K,K,C]
    _, ho, wo = patches.shape[:3]
    m = b * ho * wo
    p2 = patches.reshape(m, k * k, c)
    w2 = w.reshape(k * k, c)
    bm_ = pick_block(m, bm)
    bc_ = pick_block(c, bc)
    p2 = pad_to(p2, 0, bm_)
    p2 = pad_to(p2, 2, bc_)
    w2 = pad_to(w2, 1, bc_)
    mp, _, cp = p2.shape
    kernel = functools.partial(_dw_kernel, mode=mode)
    out = pl.pallas_call(
        kernel,
        grid=(cdiv(mp, bm_), cdiv(cp, bc_)),
        in_specs=[
            pl.BlockSpec((bm_, k * k, bc_), lambda i, j: (i, 0, j)),
            pl.BlockSpec((k * k, bc_), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bc_), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, cp), jnp.float32),
        interpret=True,
    )(p2, w2)
    return out[:m, :c].reshape(b, ho, wo, c)
