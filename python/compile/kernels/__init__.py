"""L1: Pallas kernels for NASA's hybrid operators + pure-jnp oracles.

conv_pw  — multiplication-based pointwise conv (tiled matmul, CLP work)
shift_pw — DeepShift-Q pointwise layer (fused pow2-quant matmul, SLP work)
adder_pw — AdderNet l1-distance pointwise layer (ALP work)
dw_apply — depthwise KxK layer in conv/shift/adder flavours
ref      — ground-truth jnp semantics for all of the above
"""

from .adder_pw import adder_pw
from .conv_pw import conv_pw
from .dw_conv import dw_apply
from .shift_pw import shift_pw

__all__ = ["adder_pw", "conv_pw", "dw_apply", "shift_pw"]
