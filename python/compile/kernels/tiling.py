"""Shared tiling utilities for the Pallas kernels.

TPU mapping notes (DESIGN.md §Hardware-Adaptation): blocks are chosen
MXU/VPU-shaped — multiples of 8 in the sublane dim and 128 in the lane dim
when the problem is big enough — and shrunk for the small CIFAR-scale
problems in this reproduction so that interpret=True stays fast. The
BlockSpec index maps below express the HBM->VMEM schedule the paper's
accelerator expresses with per-chunk dataflows.
"""

from __future__ import annotations

import jax.numpy as jnp

# Lane/sublane quanta of the TPU vector unit; full-size MXU tiles are
# 128x128. We tile to these when dims allow, else to the dim itself.
SUBLANE = 8
LANE = 128


def pick_block(dim: int, target: int) -> int:
    """Largest divisor-friendly block <= target for `dim` (>=1)."""
    if dim <= target:
        return dim
    # prefer an exact divisor of the padded dim; we pad to multiples anyway,
    # so just use the target.
    return target


def pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    """Zero-pad `axis` of x up to a multiple of `mult`."""
    d = x.shape[axis]
    rem = (-d) % mult
    if rem == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, rem)
    return jnp.pad(x, widths)


def cdiv(a: int, b: int) -> int:
    return -(-a // b)
