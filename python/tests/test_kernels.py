"""L1 correctness: Pallas kernels vs pure-jnp oracles (the CORE signal).

Hypothesis sweeps shapes; fixed cases pin the paper-relevant properties
(power-of-two weights, l1 distance, multiplication-free semantics).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import adder_pw, conv_pw, dw_apply, shift_pw
from compile.kernels import ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


# ---------------------------------------------------------------------------
# Pointwise kernels vs refs — hypothesis shape sweeps
# ---------------------------------------------------------------------------

pw_dims = st.tuples(
    st.integers(1, 70),  # M
    st.integers(1, 40),  # K
    st.integers(1, 50),  # N
)


@given(pw_dims, st.integers(0, 2**31 - 1))
def test_conv_pw_matches_ref(dims, seed):
    m, k, n = dims
    rng = np.random.default_rng(seed)
    x, w = rand(rng, m, k), rand(rng, k, n)
    np.testing.assert_allclose(conv_pw(x, w), ref.conv_pw_ref(x, w), rtol=1e-4, atol=1e-4)


@given(pw_dims, st.integers(0, 2**31 - 1))
def test_shift_pw_matches_ref(dims, seed):
    m, k, n = dims
    rng = np.random.default_rng(seed)
    x, w = rand(rng, m, k), rand(rng, k, n)
    np.testing.assert_allclose(shift_pw(x, w), ref.shift_pw_ref(x, w), rtol=1e-4, atol=1e-4)


@given(pw_dims, st.integers(0, 2**31 - 1))
def test_adder_pw_matches_ref(dims, seed):
    m, k, n = dims
    rng = np.random.default_rng(seed)
    x, w = rand(rng, m, k), rand(rng, k, n)
    np.testing.assert_allclose(adder_pw(x, w), ref.adder_pw_ref(x, w), rtol=1e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# Depthwise kernel, all modes/strides/kernel sizes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode,rf", [
    ("conv", ref.dw_conv_ref),
    ("shift", ref.dw_shift_ref),
    ("adder", ref.dw_adder_ref),
])
@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("k", [3, 5])
def test_dw_matches_ref(mode, rf, stride, k):
    rng = np.random.default_rng(k * 10 + stride)
    x = rand(rng, 2, 11, 11, 9)
    w = rand(rng, k, k, 9)
    got = dw_apply(x, w, stride=stride, mode=mode)
    want = rf(x, w, stride)
    assert got.shape == want.shape
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@given(
    st.integers(1, 3),   # batch
    st.integers(4, 13),  # hw
    st.integers(1, 12),  # channels
    st.integers(0, 2**31 - 1),
)
def test_dw_adder_shapes_hypothesis(b, hw, c, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, b, hw, hw, c)
    w = rand(rng, 3, 3, c)
    got = dw_apply(x, w, stride=1, mode="adder")
    np.testing.assert_allclose(got, ref.dw_adder_ref(x, w, 1), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Operator-family semantics (the paper's algorithmic properties)
# ---------------------------------------------------------------------------

def test_pow2_quant_is_powers_of_two():
    rng = np.random.default_rng(0)
    w = rand(rng, 64, 64)
    wq = np.asarray(ref.pow2_quant(w))
    nz = wq[np.abs(wq) > 0]
    exps = np.log2(np.abs(nz))
    np.testing.assert_allclose(exps, np.round(exps), atol=1e-6)
    # Eq. 3: sign preserved
    assert (np.sign(nz) == np.sign(np.asarray(w)[np.abs(wq) > 0])).all()


def test_pow2_quant_relative_error_bounded():
    # round(log2|w|) has at most sqrt(2)x relative error on magnitudes,
    # within the representable exponent range [2^P_MIN, 2^P_MAX] (values
    # outside clip to the range edge, like any fixed-point format).
    rng = np.random.default_rng(1)
    w = np.clip(np.abs(rng.normal(size=1000).astype(np.float32)), 2.0**ref.P_MIN, 2.0**ref.P_MAX)
    wq = np.abs(np.asarray(ref.pow2_quant(jnp.asarray(w))))
    ratio = wq / w
    assert (ratio >= 1 / np.sqrt(2) - 1e-3).all() and (ratio <= np.sqrt(2) + 1e-3).all()


def test_ps_construct_ternary_sign():
    s = jnp.asarray(np.linspace(-2, 2, 41).astype(np.float32))
    p = jnp.zeros_like(s) - 2.0
    w = np.asarray(ref.ps_construct(s, p))
    assert set(np.unique(np.sign(w))) <= {-1.0, 0.0, 1.0}
    nz = w[w != 0]
    np.testing.assert_allclose(np.abs(nz), 0.25)


def test_adder_pw_is_negative_l1():
    # identical x and w rows -> distance 0; else strictly negative
    x = jnp.asarray(np.eye(4, dtype=np.float32))
    w = x.T
    y = np.asarray(ref.adder_pw_ref(x, w))
    np.testing.assert_allclose(np.diag(y), 0.0, atol=1e-6)
    off = y[~np.eye(4, dtype=bool)]
    assert (off < 0).all()


def test_adder_masked_equals_sliced():
    rng = np.random.default_rng(3)
    x = rand(rng, 10, 12)
    w = rand(rng, 12, 7)
    kmask = jnp.asarray(([1.0] * 8 + [0.0] * 4), jnp.float32)
    got = ref.adder_pw_masked_ref(x, w, kmask)
    want = ref.adder_pw_ref(x[:, :8], w[:8, :])
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_fake_quant_levels():
    x = jnp.asarray(np.linspace(-1, 1, 101).astype(np.float32))
    q = np.asarray(ref.fake_quant_ref(x, 8, jnp.asarray(1.0)))
    # at most 255 distinct levels, symmetric range
    assert len(np.unique(q)) <= 255
    assert q.max() <= 1.0 + 1e-6 and q.min() >= -1.0 - 1e-6


def test_fake_quant_6bit_coarser_than_8bit():
    rng = np.random.default_rng(4)
    x = rand(rng, 1000)
    e8 = np.abs(np.asarray(ref.fake_quant_ref(x, 8, jnp.max(jnp.abs(x)))) - np.asarray(x)).mean()
    e6 = np.abs(np.asarray(ref.fake_quant_ref(x, 6, jnp.max(jnp.abs(x)))) - np.asarray(x)).mean()
    assert e6 > e8


def test_batch_norm_normalizes():
    rng = np.random.default_rng(5)
    x = rand(rng, 64, 8) * 5.0 + 3.0
    y = np.asarray(ref.batch_norm_ref(x, jnp.ones(8), jnp.zeros(8)))
    np.testing.assert_allclose(y.mean(axis=0), 0.0, atol=1e-4)
    np.testing.assert_allclose(y.std(axis=0), 1.0, atol=1e-2)


# ---------------------------------------------------------------------------
# Golden-vector reproduction: the committed fixtures under
# fixtures/kernel_golden/ (consumed byte-for-byte by the Rust differential
# harness, tests/kernel_differential.rs) must be exactly what
# scripts/gen_kernel_golden.py generates from these references today.
# ---------------------------------------------------------------------------


def test_kernel_golden_fixtures_reproduce_byte_for_byte():
    import importlib.util
    from pathlib import Path

    repo = Path(__file__).resolve().parents[2]
    spec = importlib.util.spec_from_file_location(
        "gen_kernel_golden", repo / "scripts" / "gen_kernel_golden.py"
    )
    gen = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(gen)

    fixture_dir = repo / "fixtures" / "kernel_golden"
    files = gen.generate_all()
    assert set(files) == {
        "pow2_quant.json",
        "pw_f32.json",
        "pw_fxp.json",
        "dw_f32.json",
        "dw_fxp.json",
    }
    for name, text in files.items():
        committed = (fixture_dir / name).read_text()
        assert committed == text, (
            f"{name} is stale — regenerate with "
            "`PYTHONPATH=python python3 scripts/gen_kernel_golden.py`"
        )
