"""L2 correctness: supernet semantics, layout consistency, child paths."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M


def tiny_cfg(space="hybrid_all", classes=10):
    return M.SupernetConfig(
        space=space,
        num_classes=classes,
        batch=4,
        input_hw=8,
        stem_ch=8,
        head_ch=16,
        plan=[(8, 1), (12, 2)],
    )


def init(cfg, seed=0, scale=0.1):
    rng = np.random.default_rng(seed)
    P = M.n_params(M.build_layout(cfg))
    return jnp.asarray(rng.normal(size=(P,)).astype(np.float32) * scale), rng


# ---------------------------------------------------------------------------
# Search-space enumeration (Table 1)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("space,n", [
    ("conv_only", 7),
    ("hybrid_shift", 13),
    ("hybrid_adder", 13),
    ("hybrid_all", 19),
])
def test_candidate_counts_match_paper(space, n):
    assert len(M.candidates(space)) == n
    assert M.candidates(space)[-1]["t"] == "skip"


def test_paper_plan_is_22_layers():
    assert len(M.paper_plan()) == 22


def test_layout_contiguous_and_typed():
    cfg = tiny_cfg()
    layout = M.build_layout(cfg)
    off = 0
    for e in layout:
        assert e["offset"] == off
        off += e["size"]
        assert e["ltype"] in ("conv", "shift", "adder", "common")
    assert off == M.n_params(layout)


def test_layout_gamma_zero_only_on_bn3():
    for e in M.build_layout(tiny_cfg()):
        if e["init"]["kind"] == "gamma_zero":
            assert "bn3/g" in e["name"]


# ---------------------------------------------------------------------------
# Gumbel-Softmax mixing (Eqs. 6-7)
# ---------------------------------------------------------------------------

def test_gs_weights_sum_to_one_over_enabled():
    alpha = jnp.zeros((2, 5))
    gumbel = jnp.zeros((2, 5))
    mask = jnp.asarray([[1, 1, 0, 0, 1], [1, 1, 1, 1, 1]], jnp.float32)
    gs = M.gumbel_softmax_weights(alpha, gumbel, mask, jnp.asarray(1.0))
    np.testing.assert_allclose(gs.sum(-1), 1.0, rtol=1e-6)
    assert gs[0, 2] == 0.0 and gs[0, 3] == 0.0


def test_gs_low_tau_approaches_onehot():
    alpha = jnp.asarray([[1.0, 0.5, 0.0]])
    gs = M.gumbel_softmax_weights(alpha, jnp.zeros((1, 3)), jnp.ones((1, 3)),
                                  jnp.asarray(0.05))
    assert gs[0, 0] > 0.99


# ---------------------------------------------------------------------------
# Masked supernet == exact sliced child at one-hot alpha
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", [
    [{"t": "conv", "e": 1, "k": 3}, {"t": "conv", "e": 6, "k": 5}],
    [{"t": "adder", "e": 3, "k": 3}, {"t": "shift", "e": 6, "k": 5}],
    [{"t": "shift", "e": 1, "k": 5}, {"t": "adder", "e": 6, "k": 3}],
    [{"t": "skip"}, {"t": "adder", "e": 3, "k": 3}],
])
def test_onehot_supernet_equals_child(arch):
    cfg = tiny_cfg()
    flat, rng = init(cfg)
    x = jnp.asarray(rng.normal(size=(4, 8, 8, 3)).astype(np.float32))
    idx = M.child_cand_indices(cfg, arch)
    L, NC = cfg.n_layers, cfg.n_cand
    alpha = np.zeros((L, NC), "f")
    mask = np.zeros((L, NC), "f")
    for l, c in enumerate(idx):
        mask[l, c] = 1.0
    logits_sup, gs = M.supernet_forward(
        cfg, flat, jnp.asarray(alpha), jnp.zeros((L, NC)), jnp.asarray(mask),
        jnp.asarray(1.0), x,
    )
    np.testing.assert_allclose(np.asarray(gs).sum(-1), 1.0, rtol=1e-5)
    child = M.make_child_infer_fn(cfg, arch, use_pallas=False)(flat, x)
    np.testing.assert_allclose(logits_sup, child, rtol=5e-3, atol=5e-3)


def test_child_pallas_equals_jnp():
    cfg = tiny_cfg()
    flat, rng = init(cfg, seed=1)
    x = jnp.asarray(rng.normal(size=(4, 8, 8, 3)).astype(np.float32))
    arch = [{"t": "adder", "e": 3, "k": 3}, {"t": "shift", "e": 1, "k": 5}]
    a = M.make_child_infer_fn(cfg, arch, use_pallas=False)(flat, x)
    b = M.make_child_infer_fn(cfg, arch, use_pallas=True)(flat, x)
    np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# Step function: loss decomposition + gradients
# ---------------------------------------------------------------------------

def run_step(cfg, flat, alpha, mask, lam=0.01, seed=2):
    rng = np.random.default_rng(seed)
    L, NC = cfg.n_layers, cfg.n_cand
    x = jnp.asarray(rng.normal(size=(cfg.batch, cfg.input_hw, cfg.input_hw, 3)).astype(np.float32))
    labels = jnp.asarray(np.arange(cfg.batch) % cfg.num_classes, jnp.int32)
    cost = jnp.ones((L, NC)) * 0.5
    step = M.make_step_fn(cfg)
    return step(flat, alpha, jnp.zeros((L, NC)), mask, jnp.asarray(2.0),
                jnp.asarray(lam), cost, x, labels)


def test_step_loss_decomposition_and_grads():
    cfg = tiny_cfg()
    flat, _ = init(cfg)
    L, NC = cfg.n_layers, cfg.n_cand
    alpha = jnp.zeros((L, NC))
    mask = jnp.ones((L, NC))
    loss, ce, hw, ncorrect, dflat, dalpha = run_step(cfg, flat, alpha, mask)
    np.testing.assert_allclose(loss, ce + 0.01 * hw, rtol=1e-5)
    assert 0 <= float(ncorrect) <= cfg.batch
    assert np.isfinite(np.asarray(dflat)).all()
    assert np.isfinite(np.asarray(dalpha)).all()
    assert float(jnp.abs(dflat).sum()) > 0
    assert float(jnp.abs(dalpha).sum()) > 0


def test_masked_candidates_get_zero_alpha_grad():
    cfg = tiny_cfg()
    flat, _ = init(cfg)
    L, NC = cfg.n_layers, cfg.n_cand
    alpha = jnp.zeros((L, NC))
    mask_np = np.ones((L, NC), "f")
    mask_np[0, 3] = 0.0
    *_, dalpha = run_step(cfg, flat, alpha, jnp.asarray(mask_np))
    assert abs(float(dalpha[0, 3])) < 1e-12


def test_hw_loss_scales_with_lambda():
    cfg = tiny_cfg()
    flat, _ = init(cfg)
    L, NC = cfg.n_layers, cfg.n_cand
    alpha, mask = jnp.zeros((L, NC)), jnp.ones((L, NC))
    l0, ce0, *_ = run_step(cfg, flat, alpha, mask, lam=0.0)
    l1, ce1, hw1, *_ = run_step(cfg, flat, alpha, mask, lam=1.0)
    np.testing.assert_allclose(float(ce0), float(ce1), rtol=1e-6)
    assert float(l1) > float(l0)


# ---------------------------------------------------------------------------
# Quantized eval path
# ---------------------------------------------------------------------------

def test_quant_eval_close_but_not_identical():
    cfg = tiny_cfg()
    flat, rng = init(cfg, seed=3)
    L, NC = cfg.n_layers, cfg.n_cand
    arch = [{"t": "conv", "e": 3, "k": 3}, {"t": "shift", "e": 3, "k": 3}]
    idx = M.child_cand_indices(cfg, arch)
    alpha = np.zeros((L, NC), "f")
    mask = np.zeros((L, NC), "f")
    for l, c in enumerate(idx):
        mask[l, c] = 1.0
    x = jnp.asarray(rng.normal(size=(4, 8, 8, 3)).astype(np.float32))
    labels = jnp.asarray([0, 1, 2, 3], jnp.int32)
    fp = M.make_eval_fn(cfg, quant=False)(flat, jnp.asarray(alpha), jnp.asarray(mask),
                                          jnp.asarray(1.0), x, labels)
    q = M.make_eval_fn(cfg, quant=True)(flat, jnp.asarray(alpha), jnp.asarray(mask),
                                        jnp.asarray(1.0), x, labels)
    lf, lq = np.asarray(fp[2]), np.asarray(q[2])
    assert not np.allclose(lf, lq)  # quantization must do something
    # but not destroy the representation
    corr = np.corrcoef(lf.ravel(), lq.ravel())[0, 1]
    assert corr > 0.7, f"quant destroyed logits, corr={corr}"


# ---------------------------------------------------------------------------
# All four spaces lower + run
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("space", list(M.SPACE_TYPES))
def test_all_spaces_forward(space):
    cfg = tiny_cfg(space)
    flat, rng = init(cfg, seed=4)
    L, NC = cfg.n_layers, cfg.n_cand
    x = jnp.asarray(rng.normal(size=(4, 8, 8, 3)).astype(np.float32))
    logits, gs = M.supernet_forward(
        cfg, flat, jnp.zeros((L, NC)), jnp.zeros((L, NC)), jnp.ones((L, NC)),
        jnp.asarray(5.0), x,
    )
    assert logits.shape == (4, 10)
    assert np.isfinite(np.asarray(logits)).all()
