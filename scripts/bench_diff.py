#!/usr/bin/env python3
"""Diff a fresh BENCH_mapper.json against the committed baseline.

Three record classes, three policies:

* Wall-time records ({"bench", "mean_ns", ...}) are ADVISORY: drift
  beyond +/-20% is printed but never fatal — CI machines vary.
* Speedup/cost records ({"bench", "ratio"}) are ADVISORY too: drift
  beyond +/-20% is printed, and any fresh "cost_ratio_" record above
  2.0 gets a WARN line (the EXPERIMENTS.md acceptance gauge: frontier +
  lattice-on must stay within 2x of greedy + lattice-off).
* Structural counters ({"bench", "value"}) whose name contains
  "combos" are a HARD gate in one direction: a value smaller than the
  baseline (or a counter missing from the fresh run) means the mapper's
  search space silently shrank, and the script exits nonzero. Growth is
  fine and merely noted. Other value records (e.g. EDP-quality ratios)
  are advisory.

Usage: bench_diff.py <baseline.json> <fresh.json>
"""

import json
import sys

DRIFT = 0.20
COST_RATIO_CEILING = 2.0


def load(path):
    with open(path) as f:
        recs = json.load(f)
    return {r["bench"]: r for r in recs if isinstance(r, dict) and "bench" in r}


def main(argv):
    if len(argv) != 3:
        print(__doc__.strip(), file=sys.stderr)
        return 2
    base, fresh = load(argv[1]), load(argv[2])
    shared = sorted(set(base) & set(fresh))
    failures = []
    for name in shared:
        b, f = base[name], fresh[name]
        if "value" in b and "value" in f:
            if "combos" in name and f["value"] < b["value"]:
                failures.append(
                    f"{name}: search-space counter shrank {b['value']} -> {f['value']}"
                )
            elif f["value"] != b["value"]:
                print(f"note  {name}: value {b['value']} -> {f['value']}")
        elif b.get("ratio") and f.get("ratio"):
            rel = f["ratio"] / b["ratio"]
            if rel > 1.0 + DRIFT or rel < 1.0 - DRIFT:
                print(
                    f"drift {name}: ratio {b['ratio']:.2f} -> {f['ratio']:.2f} "
                    f"({rel:.2f}x, advisory)"
                )
        elif b.get("mean_ns") and f.get("mean_ns"):
            ratio = f["mean_ns"] / b["mean_ns"]
            if ratio > 1.0 + DRIFT or ratio < 1.0 - DRIFT:
                print(
                    f"drift {name}: mean {b['mean_ns']:.0f}ns -> "
                    f"{f['mean_ns']:.0f}ns ({ratio:.2f}x, advisory)"
                )
    # The EXPERIMENTS.md acceptance gauge, checked on the fresh run alone
    # so it fires even for records the baseline predates.
    for name, f in sorted(fresh.items()):
        if "cost_ratio_" in name and (f.get("ratio") or 0) > COST_RATIO_CEILING:
            print(
                f"WARN  {name}: {f['ratio']:.2f} exceeds the {COST_RATIO_CEILING}x "
                "acceptance gauge (advisory)"
            )
    for name in sorted(set(base) - set(fresh)):
        if "value" in base[name] and "combos" in name:
            failures.append(f"{name}: search-space counter missing from fresh run")
    if failures:
        for msg in failures:
            print(f"FAIL  {msg}", file=sys.stderr)
        return 1
    print(f"bench diff OK ({len(shared)} shared records, walltime advisory +/-{DRIFT:.0%})")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
