#!/usr/bin/env python3
"""Generate the committed golden vectors under fixtures/kernel_golden/.

The Rust CPU kernels (rust/src/kernels/) are pinned against the Python
references in python/compile/kernels/ref.py two ways:

  * f32 cases — inputs/weights plus ref.py's own outputs, every f32
    stored as its u32 bit pattern (JSON floats would not round-trip
    bytes). Rust compares within a pinned relative tolerance (jnp picks
    its own reduction order, so bit equality is not owed there).
  * integer (FXP) cases — quantized codes and i64 accumulators computed
    in exact Python integer arithmetic; Rust must match byte-for-byte.

Shift weights go through an EXACT mirror of the Rust pow2 decision
(exponent from the f32 bit pattern, round boundary decided by the exact
f64 comparison |w|^2 < 2^(2e+1)); the generator cross-checks it against
ref.pow2_quant on every sampled weight and refuses to emit fixtures on
any disagreement. Sampled shift weights are nudged off the rounding
boundary first so float32 log2 in ref.py cannot land on the other side.

Output is deterministic byte-for-byte (seeded legacy RandomState, sorted
keys, fixed separators, trailing newline); python/tests/test_kernels.py
re-runs generate_all() and diffs against the committed files.

Run from the repo root:  PYTHONPATH=python python3 scripts/gen_kernel_golden.py
"""

from __future__ import annotations

import json
import math
import struct
import sys
from pathlib import Path

import numpy as np

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "python"))

from compile.kernels import ref  # noqa: E402

SEED = 0x6010D
P_MIN, P_MAX = -14, 0


# ---------------------------------------------------------------------------
# f32 <-> u32 bit plumbing
# ---------------------------------------------------------------------------


def f32_bits(x: np.float32) -> int:
    return struct.unpack("<I", struct.pack("<f", float(np.float32(x))))[0]


def bits_list(a: np.ndarray) -> list[int]:
    return [f32_bits(v) for v in np.asarray(a, dtype=np.float32).ravel()]


# ---------------------------------------------------------------------------
# exact mirror of rust's kernels::pow2_quant_one
# ---------------------------------------------------------------------------


def pow2_code(w: np.float32) -> tuple[int, int]:
    """(s, p) with s in {-1,0,1}: the identical decision Rust makes."""
    wf = float(np.float32(w))  # exact f32 -> f64
    a = abs(wf)
    if not (a >= 2.0**-15) or math.isnan(a):
        return (0, 0)
    ef = ((f32_bits(np.float32(a)) >> 23) & 0xFF) - 127
    a2 = a * a  # one f64 rounding, same as Rust's `a as f64 * a as f64`
    e = ef if a2 < 2.0 ** (2 * ef + 1) else ef + 1
    p = min(max(e, P_MIN), P_MAX)
    return ((-1 if wf < 0.0 else 1), p)


def shift_weights(rng: np.random.RandomState, n: int) -> np.ndarray:
    """Sample f32 weights kept away from the pow2 rounding boundary, so the
    float32 log2 in ref.pow2_quant and the exact decision agree."""
    w = (rng.standard_normal(n) * 0.3).astype(np.float32)
    for i in range(n):
        for _ in range(64):
            a = abs(float(np.float32(w[i])))
            if a < 2.0**-16 and a != 0.0:
                w[i] = np.float32(0.0)  # park sub-threshold noise at zero
                continue
            if a == 0.0:
                break
            t = math.log2(a)
            # Distance to the nearest half-integer rounding boundary.
            d = abs(((t - 0.5) % 1.0) - 0.5)
            if d > 1e-4:
                break
            w[i] = np.float32(float(w[i]) * 1.0009)
        else:
            raise RuntimeError(f"could not nudge weight {w[i]} off the pow2 boundary")
    return w


def check_codes_match_ref(w: np.ndarray) -> list[tuple[int, int]]:
    codes = [pow2_code(v) for v in np.asarray(w, dtype=np.float32).ravel()]
    got = np.asarray(ref.pow2_quant(np.asarray(w, dtype=np.float32)), dtype=np.float32).ravel()
    for i, ((s, p), rv) in enumerate(zip(codes, got)):
        want = np.float32(s * 2.0**p)
        if f32_bits(want) != f32_bits(rv):
            raise RuntimeError(
                f"pow2 mirror disagrees with ref.pow2_quant at [{i}]: "
                f"w={w.ravel()[i]!r} mirror={want!r} ref={rv!r} — bump SEED"
            )
    return codes


# ---------------------------------------------------------------------------
# pure-integer FXP references (exact; Rust must match byte-for-byte)
# ---------------------------------------------------------------------------


def pw_fxp_acc(kind: str, xq, wq, codes, m: int, k: int, n: int) -> list[int]:
    out = []
    for i in range(m):
        for j in range(n):
            acc = 0
            for t in range(k):
                if kind == "conv":
                    acc += xq[i * k + t] * wq[t * n + j]
                elif kind == "adder":
                    acc += abs(xq[i * k + t] - wq[t * n + j])
                else:  # shift: factor s * 2^(p + 14), applied by multiply
                    s, p = codes[t * n + j]
                    acc += xq[i * k + t] * (s << (p + 14)) if s else 0
            out.append(-acc if kind == "adder" else acc)
    return out


def dw_fxp_acc(kind: str, xq, wq, codes, b, h, w, c, k, stride) -> list[int]:
    pad = (k - 1) // 2
    ho = (h + 2 * pad - k) // stride + 1
    wo = (w + 2 * pad - k) // stride + 1
    out = []
    for bi in range(b):
        for oy in range(ho):
            for ox in range(wo):
                for ci in range(c):
                    acc = 0
                    for ki in range(k):
                        for kj in range(k):
                            iy = oy * stride + ki - pad
                            ix = ox * stride + kj - pad
                            v = (
                                xq[((bi * h + iy) * w + ix) * c + ci]
                                if 0 <= iy < h and 0 <= ix < w
                                else 0
                            )
                            wi = (ki * k + kj) * c + ci
                            if kind == "conv":
                                acc += v * wq[wi]
                            elif kind == "adder":
                                acc += abs(v - wq[wi])
                            else:
                                s, p = codes[wi]
                                acc += v * (s << (p + 14)) if s else 0
                    out.append(-acc if kind == "adder" else acc)
    return out


# ---------------------------------------------------------------------------
# fixture builders
# ---------------------------------------------------------------------------


def gen_pow2_quant(rng: np.random.RandomState) -> dict:
    # Broad magnitude sweep (2^-20 .. 2^4) plus exact powers of two, zeros
    # and both signs — every interesting region of the quantizer.
    mags = 2.0 ** rng.uniform(-20, 4, size=480)
    signs = rng.choice([-1.0, 1.0], size=480)
    w = (mags * signs).astype(np.float32)
    w = np.concatenate(
        [
            w,
            np.float32([0.0, -0.0, 2.0**-15, -(2.0**-15), 1.0, -1.0, 0.5, 100.0]),
            np.float32([2.0**p for p in range(P_MIN, P_MAX + 1)]),
        ]
    )
    # Nudge boundary-straddlers so the float32 ref agrees (same guard as
    # the shift-weight sampler, applied to the raw sweep).
    for i in range(len(w)):
        a = abs(float(w[i]))
        if a < 2.0**-15 or a == 0.0:
            continue
        t = math.log2(a)
        if abs(((t - 0.5) % 1.0) - 0.5) <= 1e-4:
            w[i] = np.float32(float(w[i]) * 1.0009)
    codes = check_codes_match_ref(w)
    return {
        "seed": SEED,
        "w_bits": bits_list(w),
        "s": [s for s, _ in codes],
        "p": [p if s else 0 for s, p in codes],
    }


PW_SHAPES = [(3, 5, 4), (4, 8, 6), (2, 16, 3), (1, 1, 1)]
DW_SHAPES = [(1, 5, 5, 2, 3, 1), (2, 6, 6, 3, 3, 2), (1, 7, 7, 2, 5, 2)]


def gen_pw_f32(rng: np.random.RandomState) -> dict:
    cases = []
    for m, k, n in PW_SHAPES:
        x = rng.standard_normal((m, k)).astype(np.float32)
        for kind in ("conv", "shift", "adder"):
            if kind == "shift":
                w = shift_weights(rng, k * n).reshape(k, n)
                check_codes_match_ref(w)
                y = ref.shift_pw_ref(x, w)
            elif kind == "conv":
                w = (rng.standard_normal((k, n)) * 0.3).astype(np.float32)
                y = ref.conv_pw_ref(x, w)
            else:
                w = (rng.standard_normal((k, n)) * 0.3).astype(np.float32)
                y = ref.adder_pw_ref(x, w)
            cases.append(
                {
                    "kind": kind,
                    "m": m,
                    "k": k,
                    "n": n,
                    "x_bits": bits_list(x),
                    "w_bits": bits_list(w),
                    "y_bits": bits_list(np.asarray(y, dtype=np.float32)),
                }
            )
    return {"seed": SEED, "cases": cases}


def gen_pw_fxp(rng: np.random.RandomState) -> dict:
    cases = []
    for m, k, n in PW_SHAPES:
        xq = [int(v) for v in rng.randint(-127, 128, size=m * k)]
        for kind in ("conv", "shift", "adder"):
            case = {"kind": kind, "m": m, "k": k, "n": n, "xq": xq}
            if kind == "shift":
                codes = [
                    (int(s), int(p))
                    for s, p in zip(
                        rng.randint(-1, 2, size=k * n), rng.randint(P_MIN, P_MAX + 1, size=k * n)
                    )
                ]
                case["s"] = [s for s, _ in codes]
                case["p"] = [p if s else 0 for s, p in codes]
                case["acc"] = pw_fxp_acc(kind, xq, None, codes, m, k, n)
            else:
                wq = [int(v) for v in rng.randint(-127, 128, size=k * n)]
                case["wq"] = wq
                case["acc"] = pw_fxp_acc(kind, xq, wq, None, m, k, n)
            cases.append(case)
    return {"seed": SEED, "cases": cases}


def gen_dw_f32(rng: np.random.RandomState) -> dict:
    cases = []
    for b, h, w_, c, k, stride in DW_SHAPES:
        x = rng.standard_normal((b, h, w_, c)).astype(np.float32)
        for kind in ("conv", "shift", "adder"):
            if kind == "shift":
                wt = shift_weights(rng, k * k * c).reshape(k, k, c)
                check_codes_match_ref(wt)
                y = ref.dw_shift_ref(x, wt, stride)
            elif kind == "conv":
                wt = (rng.standard_normal((k, k, c)) * 0.3).astype(np.float32)
                y = ref.dw_conv_ref(x, wt, stride)
            else:
                wt = (rng.standard_normal((k, k, c)) * 0.3).astype(np.float32)
                y = ref.dw_adder_ref(x, wt, stride)
            cases.append(
                {
                    "kind": kind,
                    "b": b,
                    "h": h,
                    "w": w_,
                    "c": c,
                    "k": k,
                    "stride": stride,
                    "x_bits": bits_list(x),
                    "w_bits": bits_list(wt),
                    "y_bits": bits_list(np.asarray(y, dtype=np.float32)),
                }
            )
    return {"seed": SEED, "cases": cases}


def gen_dw_fxp(rng: np.random.RandomState) -> dict:
    cases = []
    for b, h, w_, c, k, stride in DW_SHAPES:
        xq = [int(v) for v in rng.randint(-127, 128, size=b * h * w_ * c)]
        for kind in ("conv", "shift", "adder"):
            case = {
                "kind": kind,
                "b": b,
                "h": h,
                "w": w_,
                "c": c,
                "k": k,
                "stride": stride,
                "xq": xq,
            }
            if kind == "shift":
                codes = [
                    (int(s), int(p))
                    for s, p in zip(
                        rng.randint(-1, 2, size=k * k * c),
                        rng.randint(P_MIN, P_MAX + 1, size=k * k * c),
                    )
                ]
                case["s"] = [s for s, _ in codes]
                case["p"] = [p if s else 0 for s, p in codes]
                case["acc"] = dw_fxp_acc(kind, xq, None, codes, b, h, w_, c, k, stride)
            else:
                wq = [int(v) for v in rng.randint(-31, 32, size=k * k * c)]
                case["wq"] = wq
                case["acc"] = dw_fxp_acc(kind, xq, wq, None, b, h, w_, c, k, stride)
            cases.append(case)
    return {"seed": SEED, "cases": cases}


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def encode(obj: dict) -> str:
    return json.dumps(obj, sort_keys=True, separators=(",", ":")) + "\n"


def generate_all() -> dict[str, str]:
    """filename -> exact file contents; the byte-reproduction contract."""
    rng = np.random.RandomState(SEED)
    return {
        "pow2_quant.json": encode(gen_pow2_quant(rng)),
        "pw_f32.json": encode(gen_pw_f32(rng)),
        "pw_fxp.json": encode(gen_pw_fxp(rng)),
        "dw_f32.json": encode(gen_dw_f32(rng)),
        "dw_fxp.json": encode(gen_dw_fxp(rng)),
    }


def main() -> None:
    out_dir = REPO / "fixtures" / "kernel_golden"
    out_dir.mkdir(parents=True, exist_ok=True)
    for name, text in generate_all().items():
        path = out_dir / name
        path.write_text(text)
        print(f"wrote {path} ({len(text)} bytes)")


if __name__ == "__main__":
    main()
