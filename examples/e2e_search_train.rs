//! END-TO-END driver (the required full-system validation): the complete
//! NASA pipeline on the fast config —
//!
//!   1. generate the synthetic CIFAR-like workload,
//!   2. PGP pretrain + DNAS search on the hybrid-all space (L3 rust loop
//!      driving the AOT L2 graph hundreds of times),
//!   3. derive the architecture, train it from scratch (loss curve
//!      logged), evaluate FP32 and FXP8/6 accuracy,
//!   4. search the conv-only (FBNet-baseline) space with the same engine,
//!   5. map both archs onto the chunk accelerator with the auto-mapper
//!      and print the accuracy/EDP comparison (the Fig. 6 headline),
//!   6. dump Fig. 2 weight histoghram data from the trained child.
//!
//! Results land in runs/ and are summarized in EXPERIMENTS.md.
//!
//! Run: cargo run --release --example e2e_search_train
//! (fast mode: NASA_E2E_FAST=1 shrinks epochs for CI-style smoke runs)

use anyhow::{bail, Result};
use nasa::accel::{HwConfig, PeKind};
use nasa::coordinator::{run_search, train_child, Dataset, DatasetConfig, SearchConfig, TrainConfig};
use nasa::mapper::{auto_map, MapperConfig};
use nasa::model::{arch_op_counts, QuantSpec};
use nasa::report::fig6::{print_points, points_to_log, Fig6Point};
use nasa::runtime::{Engine, Manifest};
use nasa::util::json::Json;
use std::path::Path;

fn main() -> Result<()> {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        bail!("run `make artifacts` first");
    }
    let fast = std::env::var("NASA_E2E_FAST").is_ok();
    // Sized for the single-core CPU-PJRT testbed (~5s per hybrid-all step
    // at LLVM -O0): full mode ~25-30 min end to end.
    let (pretrain, search_epochs, steps, train_epochs) =
        if fast { (3, 3, 4, 4) } else { (6, 6, 8, 10) };

    let manifest = Manifest::load(dir)?;
    let runs = Path::new("runs");
    std::fs::create_dir_all(runs)?;
    let engine = Engine::cpu()?;
    let q = QuantSpec::default();
    let hw = HwConfig::eyeriss_class();

    let mut fig6_points = Vec::new();

    // ---- search + train on both spaces with the same engine/loop ----
    for space in ["hybrid_all_c10", "conv_only_c10"] {
        let sn = manifest.supernet(space)?;
        let dataset = Dataset::generate(DatasetConfig::cifar10_like(sn.input_hw));
        println!("\n=== [{space}] NAS search (PGP where applicable) ===");
        let mut cfg = SearchConfig::for_space(space, pretrain, search_epochs);
        cfg.steps_per_epoch = steps;
        let t0 = std::time::Instant::now();
        let outcome = run_search(&engine, &manifest, &dataset, &cfg)?;
        println!(
            "search: {:.1}s, choices {:?}, final train acc {:.3}",
            t0.elapsed().as_secs_f64(),
            outcome.choices,
            outcome.log.curve("train_acc").unwrap().tail_mean(3)
        );
        outcome.log.save(runs)?;
        outcome.arch.save(&runs.join(format!("arch_{space}.json")))?;

        println!("=== [{space}] train derived child from scratch ===");
        let mut tcfg = TrainConfig::for_space(space, train_epochs);
        tcfg.steps_per_epoch = steps;
        let t1 = std::time::Instant::now();
        let trained = train_child(&engine, &manifest, &dataset, &outcome.choices, &tcfg)?;
        println!(
            "train: {:.1}s, loss curve: {}",
            t1.elapsed().as_secs_f64(),
            nasa::coordinator::sparkline(&trained.log.curve("train_loss").unwrap().ys, 40)
        );
        println!(
            "test acc: FP32={:.4}  FXP8/6={:.4}",
            trained.test_acc_fp32, trained.test_acc_quant
        );
        trained.log.save(runs)?;

        // ---- hardware: auto-map onto the chunk accelerator ----
        let arch = &outcome.arch;
        let counts = arch_op_counts(arch);
        let (m, s, a) = counts.in_millions();
        println!("ops: mult={m:.2}M shift={s:.2}M add={a:.2}M");
        let accel = hw.build(arch);
        let mapped = auto_map(&accel, arch, &q, &MapperConfig::for_hw(&hw));
        let edp = match &mapped.best {
            Some((_, st)) => st.edp(accel.clock_hz),
            None => f64::NAN,
        };
        let system = if space.starts_with("conv") {
            "FBNet-like (conv-only) on NASA accel".to_string()
        } else {
            "NASA hybrid-all on NASA accel + auto-mapper".to_string()
        };
        fig6_points.push(Fig6Point { system, acc: trained.test_acc_fp32, edp_pj_s: edp });

        // Conv-only arch also on Eyeriss-MAC = the paper's FBNet baseline.
        if space.starts_with("conv") {
            let ey = hw.build_eyeriss(PeKind::Mac);
            if let Ok(st) = ey.simulate(arch, &q) {
                fig6_points.push(Fig6Point {
                    system: "FBNet-like on Eyeriss-MAC".into(),
                    acc: trained.test_acc_fp32,
                    edp_pj_s: st.edp(ey.clock_hz),
                });
            }
        }

        // ---- Fig. 2 data from the trained hybrid SUPERNET (the paper
        // plots supernet weights, so all three operator families are
        // present regardless of which candidates the search selected) ----
        if space == "hybrid_all_c10" {
            dump_fig2_weights(sn, &outcome.params, runs)?;

            // ---- conv-twin: the same searched architecture with every
            // shift/adder block replaced by the conv candidate of equal
            // (E, K) — the iso-architecture multiplication-based baseline
            // for the Fig. 6 comparison. ----
            let twin: Vec<usize> = outcome
                .choices
                .iter()
                .map(|&ci| conv_twin_choice(sn, ci))
                .collect();
            println!("=== [conv-twin of searched hybrid] train from scratch ===");
            let mut tw_cfg = TrainConfig::for_space(space, train_epochs);
            tw_cfg.steps_per_epoch = steps;
            let tw = train_child(&engine, &manifest, &dataset, &twin, &tw_cfg)?;
            println!(
                "conv-twin test acc: FP32={:.4} FXP8/6={:.4}",
                tw.test_acc_fp32, tw.test_acc_quant
            );
            let mut tw_log = tw.log;
            tw_log.name = "train_conv_twin".into();
            tw_log.save(runs)?;
            let tw_arch = nasa::model::Arch::from_choices(sn, &twin, "conv_twin")?;
            tw_arch.save(&runs.join("arch_conv_twin.json"))?;
            let ey = hw.build_eyeriss(PeKind::Mac);
            if let Ok(st) = ey.simulate(&tw_arch, &q) {
                fig6_points.push(Fig6Point {
                    system: "Conv-twin of NASA arch on Eyeriss-MAC".into(),
                    acc: tw.test_acc_fp32,
                    edp_pj_s: st.edp(ey.clock_hz),
                });
            }
        }
    }

    print_points(&fig6_points);
    points_to_log(&fig6_points, "fig6_e2e").save(runs)?;
    println!("\nE2E pipeline complete; artifacts in runs/");
    Ok(())
}

/// Map a hybrid candidate index to the conv candidate with equal (E, K).
fn conv_twin_choice(sn: &nasa::runtime::SupernetManifest, ci: usize) -> usize {
    let cand = &sn.cands[ci];
    if cand.is_skip() || cand.t == "conv" {
        return ci;
    }
    sn.cands
        .iter()
        .position(|c| c.t == "conv" && c.e == cand.e && c.k == cand.k)
        .expect("conv candidate with matching (E,K)")
}

/// Collect trained supernet weights per operator family (Fig. 2): conv
/// weights raw, shift weights after DeepShift-Q pow2 quantization, adder
/// weights raw — across ALL candidate blocks (the paper plots supernet
/// weights of a searched hybrid-all model).
fn dump_fig2_weights(
    sn: &nasa::runtime::SupernetManifest,
    params: &[f32],
    runs: &Path,
) -> Result<()> {
    let mut conv = Vec::new();
    let mut shift_q = Vec::new();
    let mut adder = Vec::new();
    for e in &sn.layout {
        let is_weight = e.name.ends_with("/pw1") || e.name.ends_with("/pw2") || e.name.ends_with("/dw");
        if !is_weight {
            continue;
        }
        let w = &params[e.offset..e.offset + e.size];
        match e.ltype.as_str() {
            "conv" => conv.extend_from_slice(w),
            "shift" => shift_q.extend(w.iter().map(|&v| pow2_quant(v))),
            "adder" => adder.extend_from_slice(w),
            _ => {}
        }
    }
    let sub = |v: &[f32]| -> Vec<f32> { v.iter().step_by((v.len() / 4000).max(1)).cloned().collect() };
    let j = Json::obj(vec![
        ("conv", Json::arr_f32(&sub(&conv))),
        ("shift_q", Json::arr_f32(&sub(&shift_q))),
        ("adder", Json::arr_f32(&sub(&adder))),
    ]);
    std::fs::write(runs.join("fig2_weights.json"), j.to_string())?;
    for (name, w) in [("conv", &conv), ("shift_q", &shift_q), ("adder", &adder)] {
        if !w.is_empty() {
            let s = nasa::report::fig2::weight_stats(w);
            println!(
                "fig2[{name}]: n={} std={:.4} excess_kurtosis={:+.2}",
                s.n, s.std, s.excess_kurtosis
            );
        }
    }
    Ok(())
}

/// DeepShift-Q (Eq. 3) on the host, mirroring kernels/ref.py.
fn pow2_quant(w: f32) -> f32 {
    if w.abs() < 2.0f32.powi(-15) {
        return 0.0;
    }
    let p = (w.abs().log2()).round().clamp(-14.0, 0.0);
    w.signum() * 2.0f32.powf(p)
}
