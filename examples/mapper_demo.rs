//! Auto-mapper anatomy: for one hybrid model, show the full dataflow
//! search — all 64 per-chunk ordering combinations, the resource-split
//! candidates, per-layer tiling choices, and why the expert all-RS
//! mapping loses (Sec. 4.2 / Fig. 8 intuition).
//!
//! Run: cargo run --release --example mapper_demo

use nasa::accel::{HwConfig, Mapping, ALL_DATAFLOWS};
use nasa::mapper::{auto_map, MapperConfig};
use nasa::model::{Arch, LayerDesc, OpKind, QuantSpec};

fn demo_arch() -> Arch {
    let mk = |name: &str, kind, cin: usize, cout: usize, hw: usize, k: usize, groups: usize| LayerDesc {
        name: name.into(),
        kind,
        cin,
        cout,
        h_out: hw,
        w_out: hw,
        k,
        stride: 1,
        groups,
    };
    Arch {
        name: "mapper_demo".into(),
        layers: vec![
            mk("stem", OpKind::Conv, 3, 16, 16, 3, 1),
            mk("conv_pw", OpKind::Conv, 16, 96, 16, 1, 1),
            mk("shift_dw", OpKind::Shift, 96, 96, 8, 5, 96),
            mk("shift_pw", OpKind::Shift, 96, 32, 8, 1, 1),
            mk("adder_pw", OpKind::Adder, 32, 192, 8, 1, 1),
            mk("adder_dw", OpKind::Adder, 192, 192, 4, 3, 192),
            mk("head", OpKind::Conv, 192, 128, 4, 1, 1),
        ],
        choices: vec![],
    }
}

fn main() {
    let arch = demo_arch();
    let q = QuantSpec::default();
    let hw = HwConfig::eyeriss_class();
    let accel = hw.build(&arch);
    println!(
        "model '{}' -> Eq.8 allocation CLP={} SLP={} ALP={}",
        arch.name, accel.alloc.clp, accel.alloc.slp, accel.alloc.alp
    );

    // Exhaustive view: EDP for every per-chunk dataflow combo (even split).
    println!("\nEDP by (CLP, SLP, ALP) dataflow combo (even GB split, default tiling):");
    print!("{:>14}", "");
    for a in ALL_DATAFLOWS {
        print!("{:>12}", format!("ALP={}", a.name()));
    }
    println!();
    for c in ALL_DATAFLOWS {
        for s in ALL_DATAFLOWS {
            print!("{:>14}", format!("CLP={} SLP={}", c.name(), s.name()));
            for a in ALL_DATAFLOWS {
                let m = Mapping {
                    clp_df: c,
                    slp_df: s,
                    alp_df: a,
                    tilings: vec![None; arch.layers.len()],
                    gb_split: [1.0 / 3.0; 3],
                    noc_split: [1.0 / 3.0; 3],
                };
                match accel.simulate(&arch, &m, &q) {
                    Ok(st) => print!("{:>12.3e}", st.edp(accel.clock_hz)),
                    Err(_) => print!("{:>12}", "infeas"),
                }
            }
            println!();
        }
    }

    // Full search incl. tilings + splits.
    let r = auto_map(&accel, &arch, &q, &MapperConfig::for_hw(&hw));
    println!(
        "\nfull auto-map: {} candidates evaluated, {} infeasible",
        r.combos_tried, r.combos_infeasible
    );
    if let Some((m, s)) = &r.best {
        println!(
            "best mapping: CLP={} SLP={} ALP={} gb_split=[{:.2},{:.2},{:.2}] EDP={:.3e}",
            m.clp_df.name(),
            m.slp_df.name(),
            m.alp_df.name(),
            m.gb_split[0],
            m.gb_split[1],
            m.gb_split[2],
            s.edp(accel.clock_hz)
        );
        println!("per-layer tilings (tm x tn):");
        for (l, t) in arch.layers.iter().zip(&m.tilings) {
            if let Some(t) = t {
                println!("  {:<10} {:>4} x {:<4}", l.name, t.tm, t.tn);
            }
        }
    }
    match &r.rs_baseline {
        Ok(s) => println!("expert all-RS: EDP={:.3e}", s.edp(accel.clock_hz)),
        Err((i, e)) => println!("expert all-RS: INFEASIBLE at layer {i}: {e}"),
    }
    if let Some(saving) = r.edp_saving_vs_rs(accel.clock_hz) {
        println!("auto-mapper saving vs RS: {:.1}%", saving * 100.0);
    }
}
