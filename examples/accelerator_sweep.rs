//! Accelerator design-space sweep: how the chunk-based NASA accelerator
//! behaves across area budgets, memory configurations and PE allocation
//! strategies — the domain exploration a hardware architect would run
//! before committing to a floorplan.
//!
//! Sweeps: (a) area budget 64..512 MAC-equivalents, (b) Eq. 8 vs equal
//! allocation, (c) default vs tight shared buffer, for three workloads
//! (hybrid searched-style, DeepShift-MBv2, AdderNet-MBv2).
//!
//! Run: cargo run --release --example accelerator_sweep

use nasa::accel::{AllocPolicy, HwConfig, Mapping, MemoryConfig};
use nasa::mapper::{auto_map, MapperConfig};
use nasa::model::zoo::mobilenet_v2_like;
use nasa::model::{Arch, LayerDesc, OpKind, QuantSpec};

fn hybrid_arch() -> Arch {
    let mk = |name: &str, kind, cin: usize, cout: usize, hw: usize, k: usize, groups: usize| LayerDesc {
        name: name.into(),
        kind,
        cin,
        cout,
        h_out: hw,
        w_out: hw,
        k,
        stride: 1,
        groups,
    };
    let mut layers = vec![mk("stem", OpKind::Conv, 3, 16, 16, 3, 1)];
    for (i, (kind, c, hw)) in [
        (OpKind::Conv, 16, 16),
        (OpKind::Shift, 24, 8),
        (OpKind::Adder, 24, 8),
        (OpKind::Conv, 32, 4),
        (OpKind::Shift, 32, 4),
        (OpKind::Adder, 64, 4),
    ]
    .iter()
    .enumerate()
    {
        let mid = c * 3;
        layers.push(mk(&format!("L{i}/pw1"), *kind, *c, mid, *hw, 1, 1));
        layers.push(mk(&format!("L{i}/dw"), *kind, mid, mid, *hw, 3, mid));
        layers.push(mk(&format!("L{i}/pw2"), *kind, mid, *c, *hw, 1, 1));
    }
    layers.push(mk("head", OpKind::Conv, 64, 128, 4, 1, 1));
    Arch { name: "hybrid".into(), layers, choices: vec![] }
}

fn main() {
    let q = QuantSpec::default();
    let workloads = vec![
        ("hybrid-searched", hybrid_arch()),
        ("deepshift-mbv2", mobilenet_v2_like(OpKind::Shift, 16, 10, 500)),
        ("addernet-mbv2", mobilenet_v2_like(OpKind::Adder, 16, 10, 500)),
    ];

    println!("== (a) area-budget sweep (auto-mapped EDP, default memory) ==");
    println!("{:<18} {:>8} {:>10} {:>10} {:>10}", "workload", "budget", "CLP/SLP/ALP", "period", "EDP pJ*s");
    for (name, arch) in &workloads {
        for budget_pes in [64, 128, 168, 256, 512] {
            let hw = HwConfig::with_budget_pes(budget_pes);
            let accel = hw.build(arch);
            let r = auto_map(&accel, arch, &q, &MapperConfig::for_hw(&hw));
            match r.best {
                Some((_, s)) => println!(
                    "{:<18} {:>8} {:>10} {:>10.0} {:>10.3e}",
                    name,
                    budget_pes,
                    format!("{}/{}/{}", accel.alloc.clp, accel.alloc.slp, accel.alloc.alp),
                    s.period_cycles,
                    s.edp(accel.clock_hz)
                ),
                None => println!("{name:<18} {budget_pes:>8} INFEASIBLE"),
            }
        }
    }

    println!("\n== (b) Eq. 8 proportional vs equal-split allocation (all-RS mapping) ==");
    println!("{:<18} {:>14} {:>14} {:>9}", "workload", "Eq.8 period", "equal period", "gain");
    for (name, arch) in &workloads {
        let m = Mapping::all_rs(arch.layers.len());
        let hw = HwConfig::eyeriss_class();
        let mut hw_eq = hw.clone();
        hw_eq.alloc_policy = AllocPolicy::Equal;
        let prop = hw.build(arch);
        let eq = hw_eq.build(arch);
        match (prop.simulate(arch, &m, &q), eq.simulate(arch, &m, &q)) {
            (Ok(sp), Ok(se)) => println!(
                "{:<18} {:>14.0} {:>14.0} {:>8.1}%",
                name,
                sp.period_cycles,
                se.period_cycles,
                (1.0 - sp.period_cycles / se.period_cycles) * 100.0
            ),
            _ => println!("{name:<18} (infeasible under all-RS)"),
        }
    }

    println!("\n== (c) shared-buffer pressure (auto-mapper resilience) ==");
    println!("{:<18} {:>12} {:>12} {:>14}", "workload", "default EDP", "tight EDP", "RS@tight");
    for (name, arch) in &workloads {
        let mk = |mem: MemoryConfig| {
            let mut hw = HwConfig::eyeriss_class();
            hw.mem = mem;
            let accel = hw.build(arch);
            let r = auto_map(&accel, arch, &q, &MapperConfig::for_hw(&hw));
            (accel, r)
        };
        let (a1, r1) = mk(MemoryConfig::default());
        let (a2, r2) = mk(MemoryConfig::tight());
        let rs_tight = match &r2.rs_baseline {
            Ok(s) => format!("{:.3e}", s.edp(a2.clock_hz)),
            Err((i, _)) => format!("INFEASIBLE@{i}"),
        };
        println!(
            "{:<18} {:>12} {:>12} {:>14}",
            name,
            r1.best.map(|(_, s)| format!("{:.3e}", s.edp(a1.clock_hz))).unwrap_or("-".into()),
            r2.best.map(|(_, s)| format!("{:.3e}", s.edp(a2.clock_hz))).unwrap_or("-".into()),
            rs_tight
        );
    }
    println!("\naccelerator sweep complete");
}
