//! Quickstart: the three-layer stack in one page.
//!
//! 1. Load the manifest + a Pallas-kernel artifact produced by
//!    `make artifacts` (python runs once, never again).
//! 2. Execute the fixed hybrid child (conv + shift + adder blocks) through
//!    PJRT from rust, cross-checking the Pallas and jnp lowerings.
//! 3. Run the same architecture through the NASA chunk-based accelerator
//!    model + auto-mapper and print op counts and EDP.
//!
//! Run: cargo run --release --example quickstart

use anyhow::{bail, Result};
use nasa::accel::HwConfig;
use nasa::mapper::{auto_map, MapperConfig};
use nasa::model::{arch_op_counts, Arch, QuantSpec};
use nasa::nas::init_params;
use nasa::runtime::{lit_f32, Engine, Manifest};
use nasa::util::rng::Rng;
use std::path::Path;

fn main() -> Result<()> {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        bail!("run `make artifacts` first");
    }
    let manifest = Manifest::load(dir)?;
    let Some(fc) = &manifest.fixed_child else { bail!("fixed child not in manifest") };
    let sn = manifest.supernet(&fc.space_key)?;
    println!(
        "supernet '{}': {} searchable layers x {} candidates, {} params",
        sn.space, sn.n_layers, sn.n_cand, sn.n_params
    );

    // --- L1/L2 on the rust request path ---
    let engine = Engine::cpu()?;
    let pallas = engine.load(&manifest.dir, &fc.pallas)?;
    let jnp = engine.load(&manifest.dir, &fc.jnp)?;
    let mut rng = Rng::new(0);
    let params = init_params(sn, &mut rng, false)?;
    let mut x = vec![0.0f32; sn.batch * sn.input_hw * sn.input_hw * sn.input_ch];
    for v in x.iter_mut() {
        *v = rng.normal() as f32;
    }
    let inputs = vec![
        lit_f32(&[sn.n_params], &params)?,
        lit_f32(&[sn.batch, sn.input_hw, sn.input_hw, sn.input_ch], &x)?,
    ];
    let lp = pallas.run(&inputs)?[0].to_vec::<f32>()?;
    let lj = jnp.run(&inputs)?[0].to_vec::<f32>()?;
    let max_diff = lp
        .iter()
        .zip(&lj)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!(
        "fixed hybrid child logits: batch {} x {} classes; pallas-vs-jnp max |diff| = {max_diff:.2e}",
        sn.batch, sn.num_classes
    );

    // --- the same arch on the NASA accelerator (L3 hardware side) ---
    let choices = fc.cand_indices.clone();
    let arch = Arch::from_choices(sn, &choices, "fixed_child")?;
    let counts = arch_op_counts(&arch);
    let (m, s, a) = counts.in_millions();
    println!("ops: mult={m:.2}M shift={s:.2}M add={a:.2}M");

    let hw = HwConfig::eyeriss_class();
    let accel = hw.build(&arch);
    println!(
        "Eq.8 PE allocation under a 168-MAC-equivalent area budget: CLP={} SLP={} ALP={}",
        accel.alloc.clp, accel.alloc.slp, accel.alloc.alp
    );
    let r = auto_map(&accel, &arch, &QuantSpec::default(), &MapperConfig::for_hw(&hw));
    if let Some((mapping, stats)) = &r.best {
        println!(
            "auto-mapped dataflows: CLP={} SLP={} ALP={} -> EDP {:.3e} pJ*s",
            mapping.clp_df.name(),
            mapping.slp_df.name(),
            mapping.alp_df.name(),
            stats.edp(accel.clock_hz)
        );
    }
    if let Some(saving) = r.edp_saving_vs_rs(accel.clock_hz) {
        println!("saving vs expert all-RS mapping: {:.1}%", saving * 100.0);
    }
    println!("quickstart OK");
    Ok(())
}
