#!/usr/bin/env sh
# ci.sh — the repository's verification gate.
#
# Usage:
#   ./ci.sh            tier-1 verify + type/doc hygiene (fmt advisory)
#   ./ci.sh --strict   additionally fail on rustfmt diffs
#
# Tier-1 (the hard gate, mirrored by the project driver):
#   cargo build --release && cargo test -q
# `cargo test` includes the kernel differential harness
# (tests/kernel_differential.rs): every native multiplication-free kernel
# vs its naive oracle over seeded shape/tiling grids, plus the committed
# Python-generated golden vectors in fixtures/kernel_golden/. It also
# includes the steady-state allocation-regression binary
# (tests/alloc_regression.rs): the prepacked cpu hot path must stay
# (nearly) allocation-free and strictly below the legacy path.

set -eu

STRICT=0
[ "${1:-}" = "--strict" ] && STRICT=1

say() { printf '\n==> %s\n' "$*"; }

say "tier-1: cargo build --release"
cargo build --release

say "tier-1: cargo test -q"
cargo test -q

say "pjrt path stays type-clean: cargo check --features pjrt"
cargo check --features pjrt

say "benches + examples compile: cargo build --release --all-targets"
cargo build --release --all-targets

say "sweep orchestrator smoke: nasa sweep (2 tiny configs, stub backend)"
# Exercises the parallel checkpointed orchestrator end to end against the
# committed fixtures/tiny_manifest (no HLO files needed on the stub
# backend): grid expansion, concurrent workers over one shared engine,
# stage-boundary checkpoints, log/arch emission — then a --resume rerun
# that must replay instantly from the end-of-run checkpoints.
rm -rf target/ci_sweep
cargo run --release --quiet -- sweep --artifacts fixtures/tiny_manifest \
    --spaces tiny --seeds 1,2 --pretrain 2 --epochs 2 --steps 2 --jobs 2 \
    --out target/ci_sweep
cargo run --release --quiet -- sweep --artifacts fixtures/tiny_manifest \
    --spaces tiny --seeds 1,2 --pretrain 2 --epochs 2 --steps 2 --jobs 2 \
    --out target/ci_sweep --resume
test -f target/ci_sweep/tiny_vanilla_recipe_s1/checkpoint.json
test -f target/ci_sweep/arch_tiny_vanilla_recipe_s2.json

say "cosearch smoke: nasa cosearch (2 archs x 4 hw cells, resume replay)"
# Joint architecture x accelerator co-search over an explicit 2x2 hw
# grid (gb x noc, seeded from the default cell) using the two archs the
# sweep smoke just emitted: the frontier exhibit must carry its schema
# tag and a full result row per (arch, cell), and a --resume rerun must
# replay every cell from its per-cell checkpoint and reproduce
# frontier.json byte for byte.
rm -rf target/ci_cosearch
COSEARCH_ARCHS=target/ci_sweep/arch_tiny_vanilla_recipe_s1.json,target/ci_sweep/arch_tiny_vanilla_recipe_s2.json
cargo run --release --quiet -- cosearch --archs "$COSEARCH_ARCHS" \
    --gb 55296,110592 --noc 8,16 --jobs 2 --out target/ci_cosearch
cp target/ci_cosearch/cosearch/frontier.json target/ci_cosearch/frontier_fresh.json
cargo run --release --quiet -- cosearch --archs "$COSEARCH_ARCHS" \
    --gb 55296,110592 --noc 8,16 --jobs 2 --out target/ci_cosearch --resume
cmp target/ci_cosearch/frontier_fresh.json target/ci_cosearch/cosearch/frontier.json
grep -q '"schema":"cosearch_frontier_v1"' target/ci_cosearch/cosearch/frontier.json
grep -q '"n_cells":4' target/ci_cosearch/cosearch/frontier.json
grep -q '"n_archs":2' target/ci_cosearch/cosearch/frontier.json

say "serve smoke: live service + deterministic loadtest replay"
# Derive two tiny children from the committed fixture manifest, launch
# the in-process live service (closed loop, 200 requests across 4
# clients), then replay its recorded arrival trace through the
# virtual-time loadtest TWICE — the two metrics JSONs must be
# byte-identical (bit-deterministic batching), every request must
# complete (zero dropped), and p99 must be reported.
rm -rf target/ci_serve
mkdir -p target/ci_serve
cargo run --release --quiet -- derive --artifacts fixtures/tiny_manifest \
    --space tiny --choices 0,1 --name s0 --out target/ci_serve
cargo run --release --quiet -- derive --artifacts fixtures/tiny_manifest \
    --space tiny --choices 1,2 --name s1 --out target/ci_serve
SERVE_MODELS=target/ci_serve/arch_s0.json,target/ci_serve/arch_s1.json
cargo run --release --quiet -- serve --models "$SERVE_MODELS" \
    --requests 200 --clients 4 --batch-max 8 --deadline-us 2000 --seed 7 \
    --trace target/ci_serve/trace.json
cargo run --release --quiet -- loadtest --models "$SERVE_MODELS" \
    --trace target/ci_serve/trace.json --batch-max 8 --deadline-us 2000 \
    --json target/ci_serve/replay1.json
cargo run --release --quiet -- loadtest --models "$SERVE_MODELS" \
    --trace target/ci_serve/trace.json --batch-max 8 --deadline-us 2000 \
    --json target/ci_serve/replay2.json
cmp target/ci_serve/replay1.json target/ci_serve/replay2.json
grep -q '"completed":200' target/ci_serve/replay1.json
grep -q '"rejected":0' target/ci_serve/replay1.json
grep -q '"p99_us"' target/ci_serve/replay1.json
# A seeded closed-loop loadtest must be deterministic end to end too.
cargo run --release --quiet -- loadtest --models "$SERVE_MODELS" \
    --closed-loop 4 --requests 200 --seed 11 --json target/ci_serve/cl1.json
cargo run --release --quiet -- loadtest --models "$SERVE_MODELS" \
    --closed-loop 4 --requests 200 --seed 11 --json target/ci_serve/cl2.json
cmp target/ci_serve/cl1.json target/ci_serve/cl2.json

say "sharded fleet smoke: serve --shards 4 --adaptive + deterministic replay"
# The live fleet (4 batcher shards, adaptive targets, mixed SLO classes)
# must answer all 200 closed-loop requests, and its recorded trace must
# replay byte-identically through the 4-shard virtual-time scheduler with
# zero drops.
cargo run --release --quiet -- serve --models "$SERVE_MODELS" \
    --requests 200 --clients 4 --shards 4 --adaptive --interactive-frac 0.5 \
    --batch-max 8 --deadline-us 2000 --seed 9 \
    --trace target/ci_serve/trace_sharded.json
cargo run --release --quiet -- loadtest --models "$SERVE_MODELS" \
    --trace target/ci_serve/trace_sharded.json --shards 4 --adaptive \
    --batch-max 8 --deadline-us 2000 --json target/ci_serve/sh1.json
cargo run --release --quiet -- loadtest --models "$SERVE_MODELS" \
    --trace target/ci_serve/trace_sharded.json --shards 4 --adaptive \
    --batch-max 8 --deadline-us 2000 --json target/ci_serve/sh2.json
cmp target/ci_serve/sh1.json target/ci_serve/sh2.json
grep -q '"completed":200' target/ci_serve/sh1.json
grep -q '"rejected":0' target/ci_serve/sh1.json

say "scenario zoo smoke: bursty arrivals + zipf mix, trace-replay identical"
# The seeded on/off (bursty) arrival process with a skewed-popularity
# model mix must generate, serve, and save a trace whose replay is
# byte-identical (generation knobs are baked into the trace, so the
# replay needs only the scheduler flags).
cargo run --release --quiet -- loadtest --models "$SERVE_MODELS" \
    --requests 300 --rps 20000 --bursty 2000,20000 --zipf 1.2 --seed 5 \
    --shards 2 --interactive-frac 0.7 --queue-cap 4096 \
    --json target/ci_serve/burst1.json --save-trace target/ci_serve/burst_trace.json
cargo run --release --quiet -- loadtest --models "$SERVE_MODELS" \
    --trace target/ci_serve/burst_trace.json --shards 2 --queue-cap 4096 \
    --json target/ci_serve/burst2.json
cmp target/ci_serve/burst1.json target/ci_serve/burst2.json
grep -q '"completed":300' target/ci_serve/burst1.json

say "obs smoke: traced loadtest replay, valid Chrome trace, byte-identical"
# The same recorded trace replayed twice at --obs-level spans: both the
# metrics JSON (now carrying the obs counter block) and the exported
# Chrome trace-event timeline must be byte-identical — the virtual-clock
# stamping contract. The trace must be well-formed (python json.load),
# carry serve.batch_exec spans, and feed the nasa report trace profiler.
cargo run --release --quiet -- loadtest --models "$SERVE_MODELS" \
    --trace target/ci_serve/trace.json --batch-max 8 --deadline-us 2000 \
    --obs-level spans --trace-out target/ci_serve/obs1.json \
    --json target/ci_serve/obs_m1.json
cargo run --release --quiet -- loadtest --models "$SERVE_MODELS" \
    --trace target/ci_serve/trace.json --batch-max 8 --deadline-us 2000 \
    --obs-level spans --trace-out target/ci_serve/obs2.json \
    --json target/ci_serve/obs_m2.json
cmp target/ci_serve/obs1.json target/ci_serve/obs2.json
cmp target/ci_serve/obs_m1.json target/ci_serve/obs_m2.json
grep -q '"obs"' target/ci_serve/obs_m1.json
# --trace-out alone implies spans; metrics at level off stay legacy-shaped.
cargo run --release --quiet -- loadtest --models "$SERVE_MODELS" \
    --trace target/ci_serve/trace.json --batch-max 8 --deadline-us 2000 \
    --json target/ci_serve/obs_off.json
cmp target/ci_serve/replay1.json target/ci_serve/obs_off.json
python3 - <<'EOF'
import json
doc = json.load(open("target/ci_serve/obs1.json"))
evs = doc["traceEvents"]
assert evs, "trace recorded no events"
for ev in evs:
    for key in ("name", "cat", "ph", "ts", "dur", "pid", "tid"):
        assert key in ev, f"event missing {key}: {ev}"
assert any(ev["name"] == "serve.batch_exec" for ev in evs), "no batch spans"
assert doc["dropped_events"] == 0, "ci workload must fit the span ring"
print(f"obs trace OK: {len(evs)} events, counters={len(doc['counters'])}")
EOF
cargo run --release --quiet -- report trace target/ci_serve/obs1.json

say "cpu backend smoke: nasa serve --backend cpu (real kernel inference)"
# Same derived children, served through the native multiplication-free
# kernels instead of the stub: 50 closed-loop requests must all complete
# (cmd_serve bails on any drop), producing genuine input-sensitive
# argmaxes end to end with no artifacts or native deps.
cargo run --release --quiet -- serve --models "$SERVE_MODELS" \
    --backend cpu --requests 50 --clients 2 --batch-max 8 \
    --deadline-us 2000 --seed 7
# The same workload with execution-plan prepacking disabled: the legacy
# re-derive-per-request path must stay fully functional (and, per the
# differential tests, bitwise identical in its outputs).
cargo run --release --quiet -- serve --models "$SERVE_MODELS" \
    --backend cpu --no-prepack --requests 50 --clients 2 --batch-max 8 \
    --deadline-us 2000 --seed 7

say "serve perf smoke: serve_loadtest --quick --json BENCH_serve.json"
# Batched-vs-unbatched throughput exhibit (EXPERIMENTS.md §Perf
# Iterations 3-4); the bench itself asserts batch-max=8 strictly beats
# batch=1, that the seeded replay is bit-identical (stub AND cpu), and
# emits the cpu-backend rows (real-kernel wall clock, cpu-vs-stub
# speedup, modeled throughput/occupancy/p99) into the same JSON — plus
# the prepack exhibit (prepacked plans must strictly beat the legacy
# path in virtual throughput and in steady-state allocs/request).
cargo bench --bench serve_loadtest -- --quick --json BENCH_serve.json

say "serve bench baseline diff (advisory)"
if [ -f BENCH_baseline_serve.json ]; then
    python3 scripts/bench_diff.py BENCH_baseline_serve.json BENCH_serve.json
else
    cp BENCH_serve.json BENCH_baseline_serve.json
    echo "no serve baseline found -- seeded BENCH_baseline_serve.json from this run (commit it)"
fi

say "mapper perf smoke: accel_microbench --quick --json BENCH_mapper.json"
# Keeps the perf trajectory accumulating (EXPERIMENTS.md §Perf reads this
# file); --quick bounds the smoke to a few iterations per benchmark.
cargo bench --bench accel_microbench -- --quick --json BENCH_mapper.json

say "mapper bench baseline diff (advisory walltime, hard combos gate)"
# Wall-time drift beyond +/-20% is reported but never fatal; a shrinking
# mapper/combos_tried_* counter fails hard (the search space narrowed).
if [ -f BENCH_baseline_mapper.json ]; then
    python3 scripts/bench_diff.py BENCH_baseline_mapper.json BENCH_mapper.json
else
    cp BENCH_mapper.json BENCH_baseline_mapper.json
    echo "no baseline found -- seeded BENCH_baseline_mapper.json from this run (commit it)"
fi

say "docs are warning-free: cargo doc --no-deps"
RUSTDOCFLAGS="${RUSTDOCFLAGS:--D warnings}" cargo doc --no-deps --quiet

say "formatting: cargo fmt --check"
if cargo fmt --check; then
    echo "fmt: clean"
elif [ "$STRICT" = "1" ]; then
    echo "fmt: FAILED (strict mode)" >&2
    exit 1
else
    echo "fmt: diffs found (advisory — run 'cargo fmt'; use ./ci.sh --strict to enforce)"
fi

say "ci.sh OK"
